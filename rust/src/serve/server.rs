//! Multi-threaded sparse-logit server.
//!
//! # Architecture
//!
//! One accept thread, one thread per connection, and a fixed pool of
//! *shard-affine* workers over a shared [`ServeSource`] — either a plain
//! disk [`CacheReader`] or a write-through tier stack
//! ([`WriteThrough<DynSource>`]) whose misses compute via an origin and
//! backfill the cache:
//!
//! ```text
//! conn thread:  read frame -> decode -> route by owning shard of `start`
//!                 -> loan its reused RangeBlock into the worker queue
//!                    (bounded try_push)  --full--> Error{Overloaded}
//!                 -> wait for the reply (block comes back filled)
//!                 -> writev `prefix | ids | probs | offsets` from the block
//! worker i:     pop job -> source.read_range_into (the connection's block)
//!                 -> send the block back with the phase timing
//! ```
//!
//! The `Targets` frame is scatter-written ([`Response::write_targets`]):
//! the worker decodes into the connection's block and the connection thread
//! hands that block's arrays to `writev` — a served range's payload bytes
//! are moved exactly once (block → socket), never staged in an intermediate
//! buffer. The `responses_vectored` stat counts these sends.
//!
//! * **Shard affinity.** A range request is routed to worker
//!   `owning_shard(start) % workers`, so concurrent requests for the same
//!   region serialize on one worker and hit the decoded-shard LRU instead of
//!   racing the disk. Overlap *across* workers (a range spanning shards) is
//!   collapsed by the reader's single-flight loads — together these make
//!   duplicate in-flight fetches structurally impossible: every shard is
//!   read from disk at most once per residency.
//! * **Backpressure.** Worker queues are bounded ([`ServeConfig::queue_cap`]
//!   per worker, admission-checked with `RingBuffer::try_push`). A full
//!   queue answers [`ErrCode::Overloaded`] immediately — the server sheds
//!   load instead of queueing unboundedly, and the client backs off.
//! * **Miss path.** Serving a write-through stack, a cold `GetRange`
//!   computes the gap via the stack's origin, quantizes, backfills the
//!   shard, and answers — so students can start distilling against a cold
//!   cache, and a second pass over the same ranges is served entirely from
//!   disk. The `Stats` frame carries the tier's hit/miss/backfill counters
//!   (`tier.*`); shard affinity doubles as miss coalescing (duplicate cold
//!   requests for one region serialize on one worker, and the tier's
//!   internal lock makes the compute single-flight regardless).
//! * **Latency accounting.** The connection thread measures accept-to-reply
//!   time (queue wait included — what a client experiences) into the
//!   log₂-bucket histogram; `Stats` exposes p50/p99 and hot-shard counters.
//!
//! Manifest/stats/ping requests are answered inline on the connection
//! thread; only range reads go through the worker pool.
//!
//! Started via [`Server::start_cluster`], the same server becomes a cluster
//! member: each `GetRange` is admission-checked against a shared
//! [`ClusterControl`] (manifest epoch + owned ranges; failures answer a
//! typed `WrongEpoch` frame), responses carry the admission-time epoch, and
//! `GetCluster` serves the shard map (standalone servers answer it
//! `BadRequest`). See [`crate::cluster`].

use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{
    CacheReader, DynSource, ProbCodec, RangeBlock, RingBuffer, TargetSource, TierCounters,
    WriteThrough,
};
use crate::cluster::ClusterControl;
use crate::fault::{self, FaultSite};
use crate::obs::{self, Phase, ServerTiming, SpanKind, SpanScope};
use crate::serve::protocol::{
    read_frame, write_frame, ErrCode, RemoteManifest, Request, Response, MAX_FRAME, NO_DEADLINE,
    NO_EPOCH, NO_TRACE, PROTOCOL_VERSION,
};
use crate::serve::stats::{ServeStats, StatsSnapshot};
use crate::serve::{Endpoint, Stream};

/// Server-side write timeout: a healthy loopback client drains responses
/// immediately, so a write blocked this long means the peer stopped reading
/// — drop the connection instead of pinning its thread (and shutdown).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// What a [`Server`] can serve: range reads plus the routing/observability
/// surface the serving layer needs. Implemented by the plain disk
/// [`CacheReader`] and by the write-through tier stack
/// ([`WriteThrough<DynSource>`]) — the server code is identical either way;
/// only the cold-read behavior differs (error vs compute-and-backfill).
pub trait ServeSource: Send + Sync + 'static {
    /// Fill `out` with `[start, start + len)` — the worker-pool hot path.
    fn read_range_into(&self, start: u64, len: usize, out: &mut RangeBlock)
        -> std::io::Result<()>;

    /// The manifest advertised to clients (spec/cache compatibility checks).
    fn remote_manifest(&self) -> RemoteManifest;

    /// Shard owning `pos`, if any — the worker-affinity routing key.
    fn shard_index_of(&self, pos: u64) -> Option<usize>;

    /// Shards in the hot-counter index space.
    fn shard_count(&self) -> usize;

    /// Visit the index of every shard overlapping `[start, end)` (hot-shard
    /// accounting).
    fn for_each_overlapping(&self, start: u64, end: u64, f: &mut dyn FnMut(usize));

    /// `(shard_loads, coalesced_loads)` of the underlying disk reader.
    fn load_counters(&self) -> (u64, u64);

    /// Tier hit/miss/backfill counters; all zero for a plain disk cache.
    fn tier_counters(&self) -> TierCounters {
        TierCounters::default()
    }
}

impl ServeSource for CacheReader {
    fn read_range_into(
        &self,
        start: u64,
        len: usize,
        out: &mut RangeBlock,
    ) -> std::io::Result<()> {
        CacheReader::read_range_into(self, start, len, out)
    }

    fn remote_manifest(&self) -> RemoteManifest {
        RemoteManifest {
            cache_version: self.version,
            positions: self.positions,
            rounds: self.rounds,
            bytes: self.bytes,
            shard_count: self.shard_count() as u32,
            kind: self.kind.clone(),
            epoch: NO_EPOCH,
        }
    }

    fn shard_index_of(&self, pos: u64) -> Option<usize> {
        CacheReader::shard_index_of(self, pos)
    }

    fn shard_count(&self) -> usize {
        CacheReader::shard_count(self)
    }

    fn for_each_overlapping(&self, start: u64, end: u64, f: &mut dyn FnMut(usize)) {
        let entries = self.entries();
        let first = entries.partition_point(|e| e.start + e.count <= start);
        for (i, e) in entries.iter().enumerate().skip(first) {
            if e.start >= end {
                break;
            }
            f(i);
        }
    }

    fn load_counters(&self) -> (u64, u64) {
        (self.shard_loads(), self.coalesced_loads())
    }
}

impl ServeSource for WriteThrough<DynSource> {
    fn read_range_into(
        &self,
        start: u64,
        len: usize,
        out: &mut RangeBlock,
    ) -> std::io::Result<()> {
        TargetSource::read_range_into(self, start, len, out)
    }

    fn remote_manifest(&self) -> RemoteManifest {
        let rounds = match self.codec() {
            ProbCodec::Count { rounds } => rounds,
            _ => 0,
        };
        RemoteManifest {
            cache_version: 2,
            positions: TargetSource::positions(self),
            rounds,
            bytes: self.flushed_bytes(),
            shard_count: ServeSource::shard_count(self) as u32,
            kind: self.kind_tag().map(|s| s.to_string()),
            epoch: NO_EPOCH,
        }
    }

    fn shard_index_of(&self, pos: u64) -> Option<usize> {
        // the write-through partition is static: every position has an
        // owning shard, cold or not — exactly what affinity routing wants
        Some((pos / self.positions_per_shard() as u64) as usize)
    }

    fn shard_count(&self) -> usize {
        let pps = self.positions_per_shard() as u64;
        (TargetSource::positions(self).div_euclid(pps)
            + u64::from(TargetSource::positions(self) % pps != 0)) as usize
    }

    fn for_each_overlapping(&self, start: u64, end: u64, f: &mut dyn FnMut(usize)) {
        if start >= end {
            return;
        }
        let pps = self.positions_per_shard() as u64;
        for shard in (start / pps)..=((end - 1) / pps) {
            f(shard as usize);
        }
    }

    fn load_counters(&self) -> (u64, u64) {
        self.reader_counters()
    }

    fn tier_counters(&self) -> TierCounters {
        self.counters()
    }
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// shard-affine worker threads performing cache reads
    pub workers: usize,
    /// bounded job-queue capacity *per worker*; the admission-control knob
    pub queue_cap: usize,
    /// largest `len` a single `GetRange` may ask for
    pub max_range: usize,
    /// how often idle connection threads poll the shutdown flag
    pub read_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            queue_cap: 64,
            max_range: 8192,
            read_timeout: Duration::from_millis(200),
        }
    }
}

/// One queued range read; the connection thread blocks on `done`. The job
/// carries the connection's reused `RangeBlock` on loan: the worker decodes
/// into it and sends it back with the reply — even on error — so the
/// connection thread scatter-writes the `Targets` frame straight from the
/// block, and serving a range never materializes per-position
/// `Vec<SparseTarget>`s or a staged payload buffer.
struct Job {
    start: u64,
    len: usize,
    /// cluster epoch stamped at admission time (the epoch the request was
    /// checked against); `NO_EPOCH` on standalone servers
    epoch: u64,
    /// trace id from the request ([`NO_TRACE`] = untraced; nonzero makes the
    /// worker open a `Server` span and echo phase timings on the response)
    trace: u64,
    /// remaining deadline budget in microseconds ([`NO_DEADLINE`] =
    /// unbounded), measured from `enqueued`: a worker popping an
    /// already-expired job sheds it instead of reading the cache for a
    /// client that has given up (docs/RESILIENCE.md §Deadlines)
    deadline_us: u32,
    /// when the connection thread queued the job — the worker measures its
    /// queue-wait phase from this
    enqueued: Instant,
    /// the connection's reused decode buffer, loaned for this job's lifetime
    block: RangeBlock,
    done: mpsc::SyncSender<(RangeBlock, Result<ServerTiming, JobError>)>,
}

/// What a connection thread writes back for one request: an owned payload
/// (every non-range exchange, and range errors), or the connection's own
/// block — filled by a worker — to scatter-write as a `Targets` frame via
/// [`Response::write_targets`].
enum Reply {
    Payload(Vec<u8>),
    Targets { epoch: u64, trace: u64, timing: ServerTiming },
}

/// Why a worker could not answer a job — kept typed so the connection
/// thread can emit the matching wire error code and bump the right counter.
enum JobError {
    /// the job's deadline budget expired before (or while) a worker could
    /// take it — answered as a typed `DeadlineExceeded` frame
    Deadline { waited: Duration },
    /// cache read failed (I/O error, panic, shutdown)
    Internal(String),
}

struct Shared {
    source: Arc<dyn ServeSource>,
    cfg: ServeConfig,
    stats: ServeStats,
    queues: Vec<Arc<RingBuffer<Job>>>,
    shutdown: AtomicBool,
    /// connection threads, joined at shutdown (accept thread pushes)
    conns: Mutex<Vec<JoinHandle<()>>>,
    /// cluster membership: epoch + owned-range enforcement
    /// (`None` = standalone server, everything admitted under `NO_EPOCH`)
    cluster: Option<Arc<ClusterControl>>,
}

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

/// A running server. Dropping it (or calling [`Server::shutdown`]) stops the
/// accept loop, drains in-flight work, and joins every thread.
pub struct Server {
    shared: Arc<Shared>,
    endpoint: Endpoint,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// unix socket file to unlink at shutdown
    unix_path: Option<PathBuf>,
}

impl Server {
    /// Bind `endpoint` and start serving `source` — an
    /// `Arc<CacheReader>` (plain disk cache) or an
    /// `Arc<WriteThrough<DynSource>>` (cold-start backfill stack).
    /// `Endpoint::Tcp` with port 0 binds an ephemeral port — read the actual
    /// one back from [`Server::endpoint`].
    pub fn start<S: ServeSource>(
        source: Arc<S>,
        endpoint: Endpoint,
        cfg: ServeConfig,
    ) -> std::io::Result<Server> {
        Server::start_with(source, endpoint, cfg, None)
    }

    /// Like [`Server::start`], but as a member of a cluster: every
    /// `GetRange` is admission-checked against `control` (manifest epoch +
    /// owned ranges — failures answer a typed `WrongEpoch` frame), responses
    /// are stamped with the admission-time epoch, and `GetCluster` serves
    /// the shard map. The caller keeps its own `Arc` to the control and
    /// drives [`ClusterControl::update`] on rebalances.
    pub fn start_cluster<S: ServeSource>(
        source: Arc<S>,
        endpoint: Endpoint,
        cfg: ServeConfig,
        control: Arc<ClusterControl>,
    ) -> std::io::Result<Server> {
        Server::start_with(source, endpoint, cfg, Some(control))
    }

    fn start_with<S: ServeSource>(
        source: Arc<S>,
        endpoint: Endpoint,
        cfg: ServeConfig,
        cluster: Option<Arc<ClusterControl>>,
    ) -> std::io::Result<Server> {
        let source: Arc<dyn ServeSource> = source;
        let workers = cfg.workers.max(1);
        let (listener, endpoint, unix_path) = match &endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)?;
                let actual = Endpoint::Tcp(l.local_addr()?);
                (Listener::Tcp(l), actual, None)
            }
            Endpoint::Unix(path) => {
                // a stale socket file from a dead server blocks bind
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                (Listener::Unix(l), Endpoint::Unix(path.clone()), Some(path.clone()))
            }
        };
        let queues: Vec<Arc<RingBuffer<Job>>> =
            (0..workers).map(|_| RingBuffer::new(cfg.queue_cap.max(1))).collect();
        let shared = Arc::new(Shared {
            stats: ServeStats::new(source.shard_count()),
            source,
            cfg,
            queues,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            cluster,
        });
        register_collector(&shared, &endpoint);
        let worker_handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, i))
            })
            .collect();
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, &shared))
        };
        Ok(Server {
            shared,
            endpoint,
            accept: Some(accept),
            workers: worker_handles,
            unix_path,
        })
    }

    /// The bound endpoint (with the actual port for `Tcp(…:0)` binds).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// Freeze every counter (serving stats + the source's load and tier
    /// counters) — same data the `Stats` wire frame carries.
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        let (loads, coalesced) = self.shared.source.load_counters();
        self.shared.stats.snapshot_with(
            loads,
            coalesced,
            self.shared.source.tier_counters(),
            epoch_of(&self.shared),
        )
    }

    /// Stop accepting, drain in-flight requests, join every thread, and (for
    /// Unix endpoints) unlink the socket file. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // the accept loop is parked in accept(); poke it with a throwaway
        // connection so it observes the flag
        match &self.endpoint {
            Endpoint::Tcp(a) => drop(TcpStream::connect(a)),
            Endpoint::Unix(p) => drop(UnixStream::connect(p)),
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // connection threads exit within read_timeout of the flag (workers
        // are still alive here, so a conn blocked on an in-flight job just
        // waits for its reply first)
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
        for q in &self.shared.queues {
            q.close();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Re-register this server's stats into the process-wide metrics registry
/// (docs/OBSERVABILITY.md): a snapshot-time collector reading the same
/// counters the `Stats` frame carries, labeled by bound endpoint so several
/// servers in one process (cluster tests, self-hosted load-gen) stay
/// distinguishable. The collector holds a `Weak` — once the server is
/// dropped it reports dead and is pruned from the registry.
fn register_collector(shared: &Arc<Shared>, endpoint: &Endpoint) {
    let weak = Arc::downgrade(shared);
    let ep = endpoint.to_string();
    obs::registry().register_collector(Box::new(move |c| {
        let Some(sh) = weak.upgrade() else { return false };
        let labels: &[(&str, &str)] = &[("endpoint", ep.as_str())];
        let s = &sh.stats;
        c.counter("rskd_serve_requests_total", labels, s.requests.load(Ordering::Relaxed));
        c.counter("rskd_serve_rejected_total", labels, s.rejected.load(Ordering::Relaxed));
        c.counter("rskd_serve_errors_total", labels, s.errors.load(Ordering::Relaxed));
        c.counter(
            "rskd_serve_wrong_epoch_total",
            labels,
            s.wrong_epoch.load(Ordering::Relaxed),
        );
        c.counter(
            "rskd_serve_deadline_exceeded_total",
            labels,
            s.deadline_exceeded.load(Ordering::Relaxed),
        );
        c.counter(
            "rskd_serve_responses_vectored_total",
            labels,
            s.responses_vectored.load(Ordering::Relaxed),
        );
        c.gauge("rskd_serve_epoch", labels, epoch_of(&sh));
        let snap = sh.stats.snapshot_with(
            0,
            0,
            sh.source.tier_counters(),
            NO_EPOCH, // counters below come from the source, not this snapshot
        );
        c.counter("rskd_serve_hot_overflow_total", labels, snap.hot_overflow);
        c.hist("rskd_serve_latency_us", labels, &snap.hist);
        let (loads, coalesced) = sh.source.load_counters();
        c.counter("rskd_shard_loads_total", labels, loads);
        c.counter("rskd_coalesced_loads_total", labels, coalesced);
        let t = snap.tier;
        c.counter("rskd_tier_hits_total", labels, t.hits);
        c.counter("rskd_tier_misses_total", labels, t.misses);
        c.counter("rskd_tier_backfilled_total", labels, t.backfilled);
        c.counter("rskd_tier_origin_computes_total", labels, t.origin_computes);
        true
    }));
}

fn accept_loop(listener: Listener, shared: &Arc<Shared>) {
    loop {
        let stream = match &listener {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
        let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
        let sh = Arc::clone(shared);
        let handle = std::thread::spawn(move || conn_loop(stream, &sh));
        let mut conns = shared.conns.lock().unwrap();
        // reap handles of finished connections so a long-lived server does
        // not accumulate one JoinHandle per connection ever accepted
        conns.retain(|h| !h.is_finished());
        conns.push(handle);
    }
}

fn worker_loop(shared: &Arc<Shared>, idx: usize) {
    let queue = Arc::clone(&shared.queues[idx]);
    while let Some(mut job) = queue.pop() {
        let queue_wait = job.enqueued.elapsed();
        // every exit below must hand the loaned block back with the reply:
        // it is the connection's reusable buffer, not this job's payload
        let mut block = std::mem::take(&mut job.block);
        // deadline admission at the worker: a job whose budget expired in
        // queue is shed typed, not served — the client's clock has already
        // moved on, and the cache read would be pure waste under overload
        if job.deadline_us != NO_DEADLINE
            && queue_wait >= Duration::from_micros(job.deadline_us as u64)
        {
            let _ = job.done.send((block, Err(JobError::Deadline { waited: queue_wait })));
            continue;
        }
        // chaos hook: per-request straggler injection (sleeps the rule's
        // delay) — what hedged reads are exercised against, since shard
        // decodes are cached and cannot straggle warm reads
        fault::fires(FaultSite::ServeJobDelay);
        // a panic must not kill the worker: its queue would keep accepting
        // jobs nobody pops, wedging every connection routed to it
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_job(shared, &job, queue_wait, &mut block)
        }))
        .unwrap_or_else(|_| {
            Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "cache read panicked serving this range",
            ))
        })
        .map_err(|e| JobError::Internal(e.to_string()));
        // a dead connection just drops the receiver; nothing to do (the
        // block goes down with the channel — the connection is gone too)
        let _ = job.done.send((block, res));
    }
}

/// One range read on a worker: decode into the job's loaned block and
/// return the phase timing to echo on the wire (zeros when untraced) — the
/// connection thread scatter-writes the frame straight from the block, so
/// there is no payload to assemble here. A traced job additionally opens a
/// `Server` span (back-dated over its queue wait), lets the tier stack
/// credit origin compute via [`obs::phase_add`], attributes the rest of the
/// read to `Decode`, and echoes the phase split so the client can derive
/// its network share.
fn serve_job(
    shared: &Shared,
    job: &Job,
    queue_wait: Duration,
    block: &mut RangeBlock,
) -> std::io::Result<ServerTiming> {
    if job.trace == NO_TRACE {
        shared.source.read_range_into(job.start, job.len, block)?;
        return Ok(ServerTiming::default());
    }
    let shard = shared.source.shard_index_of(job.start).map_or(u32::MAX, |s| s as u32);
    let mut scope = SpanScope::begin(
        obs::spans(),
        SpanKind::Server,
        job.trace,
        0,
        shard,
        job.start,
        job.len as u32,
    );
    scope.backdate(queue_wait);
    scope.span_phase(Phase::Queue, queue_wait);
    let t0 = Instant::now();
    let res = shared.source.read_range_into(job.start, job.len, block);
    let read_ns = t0.elapsed().as_nanos() as u64;
    // whatever the tier stack spent in origin compute already sits in the
    // scope's scratch; the rest of the read is decode + copy
    let origin_ns = obs::phase_scratch(Phase::Origin);
    let decode_ns = read_ns.saturating_sub(origin_ns);
    scope.span_phase(Phase::Decode, Duration::from_nanos(decode_ns));
    res?; // a failed read still records its span via the scope's Drop
    let timing =
        ServerTiming { queue_ns: queue_wait.as_nanos() as u64, decode_ns, origin_ns };
    scope.finish();
    Ok(timing)
}

/// Worker index for a range starting at `start`: the owning shard of the
/// first position, or a spread over workers for positions outside every
/// shard (still a valid request — it answers empty targets).
fn route(source: &dyn ServeSource, start: u64, workers: usize) -> usize {
    match source.shard_index_of(start) {
        Some(shard) => shard % workers,
        None => (start as usize) % workers,
    }
}

fn conn_loop(mut stream: Stream, shared: &Arc<Shared>) {
    // the connection's reused decode buffer: loaned into the worker queue
    // with each range job and returned with the reply, so once grown it
    // makes the whole serve path — decode and scatter-write — allocation-
    // and copy-free per request
    let mut block = RangeBlock::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let payload = match read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean disconnect
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        };
        let reply = match Request::decode(&payload) {
            Ok(req) => handle_request(req, shared, &mut block),
            Err(e) => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                // decide the code from the version byte itself, not from the
                // decode error's message text
                let code = match payload.first() {
                    Some(v) if *v != PROTOCOL_VERSION => ErrCode::BadVersion,
                    _ => ErrCode::BadRequest,
                };
                Reply::Payload(Response::Error { code, msg: e.to_string() }.encode())
            }
        };
        // a legal-but-huge range (misconfigured max_range vs dense targets)
        // must answer a typed error frame, not die mid-write — checked on
        // the scatter form *before* any byte is committed to the stream
        let payload_len = match &reply {
            Reply::Payload(p) => p.len(),
            Reply::Targets { .. } => Response::targets_payload_len(&block),
        };
        let reply = if payload_len > MAX_FRAME {
            shared.stats.errors.fetch_add(1, Ordering::Relaxed);
            Reply::Payload(
                Response::Error {
                    code: ErrCode::RangeTooLarge,
                    msg: format!(
                        "response of {payload_len} bytes exceeds the {MAX_FRAME}-byte frame \
                         limit; request a smaller range"
                    ),
                }
                .encode(),
            )
        } else {
            reply
        };
        // fault sites (docs/RESILIENCE.md): one relaxed load each when no
        // plan is installed. A chaos plan can make this server hang up
        // before answering (conn drop) or emit a torn length prefix and
        // hang up (stalled mid-frame write) — the client must recover via
        // reconnect-resend or replica failover, never by desyncing.
        if fault::fires(FaultSite::ServerConnDrop) {
            return;
        }
        if fault::fires(FaultSite::ServerStallWrite) {
            use std::io::Write as _;
            let n = match &reply {
                Reply::Payload(p) => p.len(),
                Reply::Targets { .. } => Response::targets_payload_len(&block),
            };
            let prefix = (n as u32).to_le_bytes();
            let _ = stream.write_all(&prefix[..2]);
            let _ = stream.flush();
            // the rule's configured delay was already slept inside fires();
            // dropping the connection now leaves the peer mid-frame
            return;
        }
        let wrote = match &reply {
            Reply::Payload(p) => write_frame(&mut stream, p).is_ok(),
            Reply::Targets { epoch, trace, timing } => {
                let ok =
                    Response::write_targets(&mut stream, &block, *epoch, *trace, *timing).is_ok();
                if ok && cfg!(target_endian = "little") {
                    // big-endian hosts took the copy fallback inside
                    // write_targets — not a vectored send
                    shared.stats.responses_vectored.fetch_add(1, Ordering::Relaxed);
                }
                ok
            }
        };
        if !wrote {
            return;
        }
    }
}

/// The cluster epoch this server currently serves under (`NO_EPOCH` when
/// standalone).
fn epoch_of(shared: &Shared) -> u64 {
    shared.cluster.as_ref().map_or(NO_EPOCH, |c| c.epoch())
}

/// Answer one request: range reads fill the connection's `block` through
/// the worker pool and come back as [`Reply::Targets`] for the scatter
/// write; everything else answers an owned, fully encoded payload.
fn handle_request(req: Request, shared: &Arc<Shared>, block: &mut RangeBlock) -> Reply {
    match req {
        Request::Ping => Reply::Payload(Response::Pong.encode()),
        Request::GetManifest => {
            let mut m = shared.source.remote_manifest();
            // a cluster member advertises the epoch it serves under, so
            // manifest-level health checks can see a rebalance land
            m.epoch = epoch_of(shared);
            Reply::Payload(Response::Manifest(m).encode())
        }
        Request::GetStats => {
            let (loads, coalesced) = shared.source.load_counters();
            Reply::Payload(
                Response::Stats(shared.stats.snapshot_with(
                    loads,
                    coalesced,
                    shared.source.tier_counters(),
                    epoch_of(shared),
                ))
                .encode(),
            )
        }
        Request::GetMetrics => {
            // the process-wide registry: this server's collector plus every
            // other subsystem registered in-process
            Reply::Payload(Response::Metrics(obs::render_global()).encode())
        }
        Request::GetTrace => Reply::Payload(Response::Trace(obs::spans().drain_ordered()).encode()),
        Request::GetCluster => match &shared.cluster {
            Some(ctl) => Reply::Payload(Response::Cluster(ctl.manifest()).encode()),
            None => {
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                Reply::Payload(
                    Response::Error {
                        code: ErrCode::BadRequest,
                        msg: "not a cluster member (standalone server)".into(),
                    }
                    .encode(),
                )
            }
        },
        Request::GetRange { start, len, epoch, trace, deadline_us } => {
            serve_range(shared, start, len as usize, epoch, trace, deadline_us, block)
        }
    }
}

fn serve_range(
    shared: &Arc<Shared>,
    start: u64,
    len: usize,
    req_epoch: u64,
    trace: u64,
    deadline_us: u32,
    block: &mut RangeBlock,
) -> Reply {
    if len > shared.cfg.max_range {
        shared.stats.errors.fetch_add(1, Ordering::Relaxed);
        return Reply::Payload(
            Response::Error {
                code: ErrCode::RangeTooLarge,
                msg: format!("len {len} exceeds max_range {}", shared.cfg.max_range),
            }
            .encode(),
        );
    }
    // wire-controlled start: a range running past u64::MAX is malformed
    let Some(end) = start.checked_add(len as u64) else {
        shared.stats.errors.fetch_add(1, Ordering::Relaxed);
        return Reply::Payload(
            Response::Error {
                code: ErrCode::BadRequest,
                msg: format!("range [{start}, +{len}) overflows the position space"),
            }
            .encode(),
        );
    };
    // Cluster admission: refuse stale epoch pins and unowned ranges with a
    // typed WrongEpoch frame. The admitted epoch is stamped into the job
    // (and thus the response) *here* — if a rebalance lands while the job is
    // queued, the response still carries the epoch it was admitted under,
    // and the reader-side pin check discards it. Standalone servers admit
    // everything under NO_EPOCH.
    let epoch = match &shared.cluster {
        None => NO_EPOCH,
        Some(ctl) => match ctl.check_range(req_epoch, start, end) {
            Ok(current) => current,
            Err(current) => {
                shared.stats.wrong_epoch.fetch_add(1, Ordering::Relaxed);
                return Reply::Payload(Response::WrongEpoch { epoch: current }.encode());
            }
        },
    };
    let t0 = Instant::now();
    let worker = route(&*shared.source, start, shared.queues.len());
    let (tx, rx) = mpsc::sync_channel(1);
    let job = Job {
        start,
        len,
        epoch,
        trace,
        deadline_us,
        enqueued: t0,
        block: std::mem::take(block),
        done: tx,
    };
    if let Err(job) = shared.queues[worker].try_push(job) {
        // the bounced job hands the connection's loaned block straight back
        *block = job.block;
        shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
        return Reply::Payload(
            Response::Error {
                code: ErrCode::Overloaded,
                msg: format!("worker {worker} queue full ({} slots)", shared.cfg.queue_cap),
            }
            .encode(),
        );
    }
    match rx.recv() {
        Ok((returned, res)) => {
            *block = returned;
            match res {
                Ok(timing) => {
                    shared.stats.requests.fetch_add(1, Ordering::Relaxed);
                    shared.stats.hist.record(t0.elapsed());
                    // hot-shard accounting: every shard the range overlaps
                    shared
                        .source
                        .for_each_overlapping(start, end, &mut |i| shared.stats.touch_shard(i));
                    Reply::Targets { epoch, trace, timing }
                }
                Err(JobError::Deadline { waited }) => {
                    shared.stats.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                    Reply::Payload(
                        Response::Error {
                            code: ErrCode::DeadlineExceeded,
                            msg: format!(
                                "deadline budget of {deadline_us} µs expired after {} µs in queue",
                                waited.as_micros()
                            ),
                        }
                        .encode(),
                    )
                }
                Err(JobError::Internal(msg)) => {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                    Reply::Payload(Response::Error { code: ErrCode::Internal, msg }.encode())
                }
            }
        }
        // the worker pool is shutting down and dropped the job (and the
        // loaned block with it — this connection is about to die anyway)
        Err(_) => Reply::Payload(
            Response::Error { code: ErrCode::Internal, msg: "server shutting down".into() }
                .encode(),
        ),
    }
}
