//! Versioned length-prefixed wire format for the sparse-logit server — see
//! `docs/SERVING.md` for the normative byte-level spec.
//!
//! Every message is one *frame*: a `u32` little-endian payload length
//! followed by the payload. The payload starts with a fixed two-byte
//! preamble — `version u8` ([`PROTOCOL_VERSION`]) and `opcode u8` — and the
//! opcode-specific body. All integers are little-endian; probabilities
//! travel as raw `f32` bits, so a served target is bit-identical to a local
//! [`CacheReader`](crate::cache::CacheReader) decode.
//!
//! Requests: `GetRange` (a contiguous position range, optionally pinned to a
//! cluster-manifest epoch and optionally carrying a trace id), `GetManifest`
//! (the directory totals + kind tag, for spec/cache compatibility checks
//! before training), `GetStats` (latency histogram + counters),
//! `GetMetrics` (the unified registry as Prometheus-style text),
//! `GetTrace` (the server's finished-span ring), `GetCluster` (the cluster
//! shard map), `Ping`.
//! Errors come back as typed [`Response::Error`] frames with an [`ErrCode`]
//! — a client can distinguish transient overload (retry with backoff) from a
//! request it must not repeat. A cluster member answers ranges it no longer
//! owns — or requests pinned to a superseded epoch — with a typed
//! [`Response::WrongEpoch`] frame carrying its current epoch, so a routed
//! reader refetches the manifest instead of silently using a stale map.

use std::io::{self, Read, Write};

use crate::cache::SparseTarget;
use crate::cluster::ClusterManifest;
use crate::obs::{ServerTiming, Span, SpanKind};
use crate::serve::stats::{StatsSnapshot, HIST_BUCKETS};
use crate::spec::{CacheKind, SpecError};

/// Current wire protocol version; bumped on any incompatible change.
/// v6 flattened the `Targets` body into one CSR block — `count`, `slots`,
/// then contiguous `ids | probs | offsets` arrays instead of per-position
/// interleaved slots — so a server scatter-writes the frame with `writev`
/// straight from its decoded [`RangeBlock`](crate::cache::RangeBlock)
/// (zero payload-assembly copies; see [`Response::write_targets`]) and a
/// client bulk-decodes the arrays; also appended the `responses_vectored`
/// counter to `Stats`. v5 added deadline propagation
/// (docs/RESILIENCE.md): a relative
/// microsecond deadline budget on `GetRange` ([`NO_DEADLINE`] = unbounded),
/// the `DeadlineExceeded` error code for jobs the server sheds because
/// their budget expired in queue, and the `deadline_exceeded` counter on
/// `Stats`. v4 added request tracing and exposition
/// (docs/OBSERVABILITY.md): a trace id on `GetRange`, a trace-id +
/// server-phase-timing echo on `Targets`, the `GetMetrics`/`Metrics` and
/// `GetTrace`/`Trace` exchanges, and the `hot_overflow` counter on
/// `Stats`. v3 added the cluster epoch to
/// `GetRange`/`Targets`/`Manifest`/`Stats`, plus the `GetCluster`/`Cluster`
/// manifest exchange and the `WrongEpoch` frame (docs/SERVING.md §Cluster).
/// v2 extended the `Stats` frame with the tiered-source counters
/// (hits/misses/backfilled/origin_computes).
pub const PROTOCOL_VERSION: u8 = 6;

/// Hard cap on a frame payload (16 MiB): a corrupt or hostile length prefix
/// must not allocate unboundedly.
pub const MAX_FRAME: usize = 16 << 20;

/// How many consecutive read-timeout wakeups `read_frame` tolerates *inside*
/// a frame before declaring the peer stalled. Only servers set read
/// timeouts, so this bounds how long a half-sent frame can pin a connection
/// thread (stalls x read_timeout); clients block indefinitely as before.
pub const MAX_FRAME_STALLS: u32 = 25;

/// Request opcodes (high bit clear).
pub const OP_GET_RANGE: u8 = 0x01;
pub const OP_GET_MANIFEST: u8 = 0x02;
pub const OP_GET_STATS: u8 = 0x03;
pub const OP_PING: u8 = 0x04;
pub const OP_GET_CLUSTER: u8 = 0x05;
pub const OP_GET_METRICS: u8 = 0x06;
pub const OP_GET_TRACE: u8 = 0x07;

/// Response opcodes (high bit set).
pub const OP_TARGETS: u8 = 0x81;
pub const OP_MANIFEST: u8 = 0x82;
pub const OP_STATS: u8 = 0x83;
pub const OP_PONG: u8 = 0x84;
pub const OP_CLUSTER: u8 = 0x85;
pub const OP_WRONG_EPOCH: u8 = 0x86;
pub const OP_METRICS: u8 = 0x87;
pub const OP_TRACE: u8 = 0x88;
pub const OP_ERROR: u8 = 0xEE;

/// The trace id meaning "untraced": standalone/unpinned requests carry it,
/// and a server answering it opens no span scope.
pub const NO_TRACE: u64 = 0;

/// The epoch value meaning "no cluster": standalone servers stamp it on
/// every `Targets` frame, and a `GetRange` carrying it skips the epoch
/// check on cluster members (ownership is still enforced).
pub const NO_EPOCH: u64 = 0;

/// Fixed-size prefix of a scatter-written v6 `Targets` frame: the `u32`
/// frame length, the 2-byte preamble, `epoch`, the 32-byte trace/timing
/// echo, `count`, and `slots`. Everything after it is the block's own
/// `ids | probs | offsets` arrays, which [`Response::write_targets`] hands
/// to `write_vectored` without staging them in a payload buffer.
pub const TARGETS_PREFIX_BYTES: usize = 4 + 2 + 8 + 32 + 4 + 4;

/// The deadline value meaning "unbounded": a `GetRange` carrying it is
/// never shed by the server's deadline check. Nonzero values are a
/// *relative* budget in microseconds — measured from frame receipt, so no
/// clock synchronization between client and server is assumed
/// (docs/RESILIENCE.md §Deadlines).
pub const NO_DEADLINE: u32 = 0;

/// Typed error codes carried by [`Response::Error`] frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// malformed frame, unknown opcode, or bad body
    BadRequest = 1,
    /// `len` exceeds the server's `max_range`
    RangeTooLarge = 2,
    /// admission control rejected the request (queue full) — retry with
    /// backoff; the only retryable code
    Overloaded = 3,
    /// server-side failure (shard I/O error, shutdown mid-request)
    Internal = 4,
    /// frame carried an unsupported protocol version
    BadVersion = 5,
    /// the request's deadline budget expired before the server could
    /// answer (shed at admission or on worker pop) — not retryable on the
    /// same budget; the caller's clock, not the server's, owns the retry
    /// decision (docs/RESILIENCE.md §Deadlines)
    DeadlineExceeded = 6,
}

impl ErrCode {
    pub fn from_u16(v: u16) -> Option<ErrCode> {
        match v {
            1 => Some(ErrCode::BadRequest),
            2 => Some(ErrCode::RangeTooLarge),
            3 => Some(ErrCode::Overloaded),
            4 => Some(ErrCode::Internal),
            5 => Some(ErrCode::BadVersion),
            6 => Some(ErrCode::DeadlineExceeded),
            _ => None,
        }
    }
}

/// The server's advertised view of the cache it serves: the directory totals
/// a [`CacheReader`](crate::cache::CacheReader) exposes locally, so a remote
/// consumer can run the same spec/cache compatibility checks.
#[derive(Clone, Debug, PartialEq)]
pub struct RemoteManifest {
    /// cache directory format version (2 for v2, 1 for legacy)
    pub cache_version: u32,
    pub positions: u64,
    pub rounds: u32,
    pub bytes: u64,
    pub shard_count: u32,
    /// canonical cache-kind string (`topk`, `rs:rounds=50,temp=1`); `None`
    /// for untagged directories
    pub kind: Option<String>,
    /// cluster-manifest epoch the server is serving under ([`NO_EPOCH`] for
    /// a standalone server)
    pub epoch: u64,
}

impl RemoteManifest {
    /// Typed kind of the served cache — same rules as
    /// `CacheReader::cache_kind` (recorded tag wins, codec inference as the
    /// untagged fallback), so `DistillSpec::check_cache` works unchanged
    /// against a remote cache.
    pub fn cache_kind(&self) -> Result<CacheKind, SpecError> {
        CacheKind::of_manifest(self.kind.as_deref(), self.rounds)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// targets for `[start, start + len)`; `epoch` pins the request to a
    /// cluster-manifest generation ([`NO_EPOCH`] = unpinned — standalone
    /// clients, or a routed reader probing after a manifest refetch).
    /// `trace` is the 64-bit trace id minted at the trainer root span
    /// ([`NO_TRACE`] = untraced) — a traced server opens a `Server` span and
    /// echoes the id plus its phase timings on the answering `Targets`
    /// frame. `deadline_us` is the request's remaining budget in
    /// microseconds ([`NO_DEADLINE`] = unbounded): a server sheds the job
    /// with a typed `DeadlineExceeded` frame once the budget expires in
    /// queue, instead of doing work the client has already given up on
    GetRange { start: u64, len: u32, epoch: u64, trace: u64, deadline_us: u32 },
    GetManifest,
    GetStats,
    /// the server's unified metrics registry snapshot, as Prometheus-style
    /// text (docs/OBSERVABILITY.md §Exposition)
    GetMetrics,
    /// the server's finished-span ring, oldest first
    GetTrace,
    GetCluster,
    Ping,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// `epoch` echoes the manifest generation the server answered under
    /// ([`NO_EPOCH`] standalone) — a routed reader discards any answer whose
    /// epoch disagrees with its manifest instead of mixing generations.
    /// `trace` echoes the request's trace id and `timing` the server's
    /// queue/decode/origin phase split (all-zero when untraced) — the
    /// serve-layer `Server-Timing` header, letting the client attribute
    /// `network = rtt − timing.total_ns()`
    Targets { epoch: u64, trace: u64, timing: ServerTiming, targets: Vec<SparseTarget> },
    Manifest(RemoteManifest),
    Stats(StatsSnapshot),
    /// Prometheus-style text rendering of the server's metrics registry
    Metrics(String),
    /// the server's retained finished spans, oldest first
    Trace(Vec<Span>),
    /// the cluster shard map (range partition + replica sets)
    Cluster(ClusterManifest),
    Pong,
    /// the range is pinned to a superseded epoch, or this member no longer
    /// owns it; `epoch` is the server's current generation — refetch the
    /// cluster manifest and re-route
    WrongEpoch { epoch: u64 },
    Error { code: ErrCode, msg: String },
}

/// What [`Response::decode_targets_into`] found: a `Targets` frame decoded
/// into the caller's block (with the answering epoch), or any other frame
/// decoded normally.
#[derive(Debug)]
pub enum RangeFrame {
    Targets { epoch: u64, trace: u64, timing: ServerTiming },
    Other(Response),
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Write one frame (length prefix + payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(bad(format!("frame payload {} exceeds MAX_FRAME", payload.len())));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Raw little-endian byte view of a `u32` array — on little-endian hosts
/// the in-memory layout *is* the wire layout, so the block's arrays go to
/// `write_vectored` without per-element conversion or a staging copy.
///
/// SAFETY: `u8` has no alignment requirement; the view covers exactly
/// `v.len() * 4` initialized bytes owned by `v`, and the shared borrow of
/// `v` pins them (unaliased by any `&mut`) for the view's lifetime.
#[cfg(target_endian = "little")]
fn le_bytes_of_u32s(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Raw little-endian byte view of an `f32` array (wire probabilities are
/// raw `f32` bits, little-endian — same layout argument as
/// [`le_bytes_of_u32s`]).
#[cfg(target_endian = "little")]
fn le_bytes_of_f32s(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// `write_all` over four scatter segments: re-slices past whatever each
/// `write_vectored` call consumed (Rust 1.70 has no stable
/// `IoSlice::advance_slices`), so short vectored writes — and `Write`
/// impls whose default `write_vectored` only consumes the first non-empty
/// buffer — still complete the frame.
#[cfg(target_endian = "little")]
fn write_all_vectored4(w: &mut impl Write, bufs: [&[u8]; 4]) -> io::Result<()> {
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    let mut written = 0usize;
    while written < total {
        let mut iov = [io::IoSlice::new(&[]); 4];
        let mut n = 0;
        let mut skip = written;
        for b in bufs.iter() {
            if skip >= b.len() {
                skip -= b.len();
                continue;
            }
            iov[n] = io::IoSlice::new(&b[skip..]);
            skip = 0;
            n += 1;
        }
        match w.write_vectored(&iov[..n]) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(k) => written += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read one frame's payload. `Ok(None)` is a clean EOF *at a frame
/// boundary* (peer hung up between requests); EOF mid-frame is an error.
/// A timeout at a frame boundary passes through untouched so servers can
/// poll a shutdown flag; timeouts *inside* a frame are retried (a timeout
/// there would desync the stream) up to [`MAX_FRAME_STALLS`] times, after
/// which the peer is declared stalled — otherwise a client that sends half
/// a frame and goes silent would pin its connection thread forever and hang
/// server shutdown.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut stalls = 0u32;
    let mut stalled = |stalls: &mut u32| -> io::Result<()> {
        *stalls += 1;
        if *stalls > MAX_FRAME_STALLS {
            return Err(bad("peer stalled mid-frame"));
        }
        Ok(())
    };
    let mut lenb = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut lenb[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(bad("EOF inside frame length prefix")),
            Ok(n) => got += n,
            Err(e) if got == 0 => return Err(e),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                stalled(&mut stalls)?;
            }
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len > MAX_FRAME {
        return Err(bad(format!("frame length {len} exceeds MAX_FRAME")));
    }
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut payload[got..]) {
            Ok(0) => return Err(bad("EOF inside frame payload")),
            Ok(n) => got += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                stalled(&mut stalls)?;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Some(payload))
}

/// Little-endian cursor over a payload body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(bad("truncated frame body"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> io::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(bad("trailing bytes in frame body"));
        }
        Ok(())
    }
}

fn preamble(opcode: u8) -> Vec<u8> {
    vec![PROTOCOL_VERSION, opcode]
}

/// The v4 trace/timing echo block shared by every `Targets` body: trace id,
/// then the server's queue/decode/origin phase nanoseconds.
fn put_trace_timing(p: &mut Vec<u8>, trace: u64, timing: ServerTiming) {
    p.extend_from_slice(&trace.to_le_bytes());
    p.extend_from_slice(&timing.queue_ns.to_le_bytes());
    p.extend_from_slice(&timing.decode_ns.to_le_bytes());
    p.extend_from_slice(&timing.origin_ns.to_le_bytes());
}

fn get_trace_timing(c: &mut Cursor<'_>) -> io::Result<(u64, ServerTiming)> {
    let trace = c.u64()?;
    let timing = ServerTiming {
        queue_ns: c.u64()?,
        decode_ns: c.u64()?,
        origin_ns: c.u64()?,
    };
    Ok((trace, timing))
}

/// Split a payload into (opcode, body), validating the version byte.
fn open_payload(payload: &[u8]) -> io::Result<(u8, Cursor<'_>)> {
    if payload.len() < 2 {
        return Err(bad("frame payload shorter than the 2-byte preamble"));
    }
    if payload[0] != PROTOCOL_VERSION {
        return Err(bad(format!(
            "unsupported protocol version {} (expected {PROTOCOL_VERSION})",
            payload[0]
        )));
    }
    Ok((payload[1], Cursor { buf: payload, pos: 2 }))
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Request::GetRange { start, len, epoch, trace, deadline_us } => {
                let mut p = preamble(OP_GET_RANGE);
                p.extend_from_slice(&start.to_le_bytes());
                p.extend_from_slice(&len.to_le_bytes());
                p.extend_from_slice(&epoch.to_le_bytes());
                p.extend_from_slice(&trace.to_le_bytes());
                p.extend_from_slice(&deadline_us.to_le_bytes());
                p
            }
            Request::GetManifest => preamble(OP_GET_MANIFEST),
            Request::GetStats => preamble(OP_GET_STATS),
            Request::GetMetrics => preamble(OP_GET_METRICS),
            Request::GetTrace => preamble(OP_GET_TRACE),
            Request::GetCluster => preamble(OP_GET_CLUSTER),
            Request::Ping => preamble(OP_PING),
        }
    }

    pub fn decode(payload: &[u8]) -> io::Result<Request> {
        let (op, mut c) = open_payload(payload)?;
        let req = match op {
            OP_GET_RANGE => Request::GetRange {
                start: c.u64()?,
                len: c.u32()?,
                epoch: c.u64()?,
                trace: c.u64()?,
                deadline_us: c.u32()?,
            },
            OP_GET_MANIFEST => Request::GetManifest,
            OP_GET_STATS => Request::GetStats,
            OP_GET_METRICS => Request::GetMetrics,
            OP_GET_TRACE => Request::GetTrace,
            OP_GET_CLUSTER => Request::GetCluster,
            OP_PING => Request::Ping,
            other => return Err(bad(format!("unknown request opcode {other:#04x}"))),
        };
        c.done()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Response::Targets { epoch, trace, timing, targets } => {
                let mut p = preamble(OP_TARGETS);
                p.extend_from_slice(&epoch.to_le_bytes());
                put_trace_timing(&mut p, *trace, *timing);
                let slots: usize = targets.iter().map(|t| t.ids.len()).sum();
                p.extend_from_slice(&(targets.len() as u32).to_le_bytes());
                p.extend_from_slice(&(slots as u32).to_le_bytes());
                for t in targets {
                    for &id in &t.ids {
                        p.extend_from_slice(&id.to_le_bytes());
                    }
                }
                for t in targets {
                    for &prob in &t.probs {
                        p.extend_from_slice(&prob.to_bits().to_le_bytes());
                    }
                }
                let mut off = 0u32;
                p.extend_from_slice(&off.to_le_bytes());
                for t in targets {
                    off += t.ids.len() as u32;
                    p.extend_from_slice(&off.to_le_bytes());
                }
                p
            }
            Response::Manifest(m) => {
                let mut p = preamble(OP_MANIFEST);
                p.extend_from_slice(&m.cache_version.to_le_bytes());
                p.extend_from_slice(&m.positions.to_le_bytes());
                p.extend_from_slice(&m.rounds.to_le_bytes());
                p.extend_from_slice(&m.bytes.to_le_bytes());
                p.extend_from_slice(&m.shard_count.to_le_bytes());
                match &m.kind {
                    None => p.push(0),
                    Some(k) => {
                        p.push(1);
                        p.extend_from_slice(&(k.len() as u16).to_le_bytes());
                        p.extend_from_slice(k.as_bytes());
                    }
                }
                p.extend_from_slice(&m.epoch.to_le_bytes());
                p
            }
            Response::Stats(s) => {
                let mut p = preamble(OP_STATS);
                for v in [
                    s.requests,
                    s.rejected,
                    s.errors,
                    s.wrong_epoch,
                    s.epoch,
                    s.shard_loads,
                    s.coalesced,
                    s.tier.hits,
                    s.tier.misses,
                    s.tier.backfilled,
                    s.tier.origin_computes,
                ] {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                debug_assert_eq!(s.hist.len(), HIST_BUCKETS);
                p.push(s.hist.len() as u8);
                for b in &s.hist {
                    p.extend_from_slice(&b.to_le_bytes());
                }
                p.extend_from_slice(&(s.hot.len() as u32).to_le_bytes());
                for h in &s.hot {
                    p.extend_from_slice(&h.to_le_bytes());
                }
                p.extend_from_slice(&s.hot_overflow.to_le_bytes());
                p.extend_from_slice(&s.deadline_exceeded.to_le_bytes());
                p.extend_from_slice(&s.responses_vectored.to_le_bytes());
                p
            }
            Response::Cluster(m) => {
                // the manifest travels in its canonical JSON form — a cold,
                // once-per-epoch exchange where self-description beats a
                // hand-rolled binary body
                let mut p = preamble(OP_CLUSTER);
                let text = m.to_json_string();
                p.extend_from_slice(&(text.len() as u32).to_le_bytes());
                p.extend_from_slice(text.as_bytes());
                p
            }
            Response::Metrics(text) => {
                let mut p = preamble(OP_METRICS);
                p.extend_from_slice(&(text.len() as u32).to_le_bytes());
                p.extend_from_slice(text.as_bytes());
                p
            }
            Response::Trace(spans) => {
                let mut p = preamble(OP_TRACE);
                p.extend_from_slice(&(spans.len() as u32).to_le_bytes());
                for s in spans {
                    p.extend_from_slice(&s.trace.to_le_bytes());
                    p.push(s.kind as u8);
                    p.extend_from_slice(&s.member.to_le_bytes());
                    p.extend_from_slice(&s.shard.to_le_bytes());
                    p.extend_from_slice(&s.start.to_le_bytes());
                    p.extend_from_slice(&s.len.to_le_bytes());
                    p.extend_from_slice(&s.total_ns.to_le_bytes());
                    for ph in &s.phases {
                        p.extend_from_slice(&ph.to_le_bytes());
                    }
                }
                p
            }
            Response::Pong => preamble(OP_PONG),
            Response::WrongEpoch { epoch } => {
                let mut p = preamble(OP_WRONG_EPOCH);
                p.extend_from_slice(&epoch.to_le_bytes());
                p
            }
            Response::Error { code, msg } => {
                let mut p = preamble(OP_ERROR);
                p.extend_from_slice(&(*code as u16).to_le_bytes());
                let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
                p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
                p.extend_from_slice(msg);
                p
            }
        }
    }

    /// Encode an `OP_TARGETS` payload straight from a CSR block — the
    /// copy-form symmetric of [`Response::decode_targets_into`]: byte-
    /// identical to the equivalent `Response::Targets { .. }.encode()`
    /// without materializing the per-position vectors. The server's hot
    /// path uses [`Response::write_targets`] instead, which never stages
    /// the array section at all; this form remains for big-endian hosts
    /// and callers that need an owned payload, and it charges the staged
    /// array bytes to the copy ledger (`rskd_io_bytes_copied_total`).
    /// `trace`/`timing` are the v4 trace echo ([`NO_TRACE`] and zeros for
    /// untraced requests).
    pub fn encode_targets(
        block: &crate::cache::RangeBlock,
        epoch: u64,
        trace: u64,
        timing: ServerTiming,
    ) -> Vec<u8> {
        let mut p = preamble(OP_TARGETS);
        p.extend_from_slice(&epoch.to_le_bytes());
        put_trace_timing(&mut p, trace, timing);
        p.extend_from_slice(&(block.len() as u32).to_le_bytes());
        p.extend_from_slice(&(block.total_slots() as u32).to_le_bytes());
        for &id in &block.ids {
            p.extend_from_slice(&id.to_le_bytes());
        }
        for &prob in &block.probs {
            p.extend_from_slice(&prob.to_bits().to_le_bytes());
        }
        for &o in &block.offsets {
            p.extend_from_slice(&o.to_le_bytes());
        }
        crate::cache::mapio::note_copied(
            (8 * block.total_slots() + 4 * block.offsets.len()) as u64,
        );
        p
    }

    /// Payload length (without the `u32` frame length prefix) of the
    /// `Targets` frame [`Response::write_targets`] / `encode_targets`
    /// produce for `block` — servers precheck it against [`MAX_FRAME`]
    /// before committing any bytes to the connection.
    pub fn targets_payload_len(block: &crate::cache::RangeBlock) -> usize {
        TARGETS_PREFIX_BYTES - 4 + 8 * block.total_slots() + 4 * block.offsets.len()
    }

    /// Scatter-write one `Targets` frame: the length prefix and payload
    /// head go in a [`TARGETS_PREFIX_BYTES`] stack buffer, then the
    /// block's `ids`/`probs`/`offsets` arrays are handed to
    /// `write_vectored` as raw little-endian byte views — the payload is
    /// never assembled in an intermediate buffer, so serving a range moves
    /// its bytes exactly once (block → socket). On the wire this is
    /// byte-identical to `write_frame(w, &Response::encode_targets(..))`;
    /// big-endian hosts fall back to exactly that copy path.
    pub fn write_targets(
        w: &mut impl Write,
        block: &crate::cache::RangeBlock,
        epoch: u64,
        trace: u64,
        timing: ServerTiming,
    ) -> io::Result<()> {
        let payload_len = Response::targets_payload_len(block);
        if payload_len > MAX_FRAME {
            return Err(bad(format!("frame payload {payload_len} exceeds MAX_FRAME")));
        }
        #[cfg(target_endian = "little")]
        {
            let mut head = [0u8; TARGETS_PREFIX_BYTES];
            head[0..4].copy_from_slice(&(payload_len as u32).to_le_bytes());
            head[4] = PROTOCOL_VERSION;
            head[5] = OP_TARGETS;
            head[6..14].copy_from_slice(&epoch.to_le_bytes());
            head[14..22].copy_from_slice(&trace.to_le_bytes());
            head[22..30].copy_from_slice(&timing.queue_ns.to_le_bytes());
            head[30..38].copy_from_slice(&timing.decode_ns.to_le_bytes());
            head[38..46].copy_from_slice(&timing.origin_ns.to_le_bytes());
            head[46..50].copy_from_slice(&(block.len() as u32).to_le_bytes());
            head[50..54].copy_from_slice(&(block.total_slots() as u32).to_le_bytes());
            write_all_vectored4(
                w,
                [
                    &head,
                    le_bytes_of_u32s(&block.ids),
                    le_bytes_of_f32s(&block.probs),
                    le_bytes_of_u32s(&block.offsets),
                ],
            )?;
            w.flush()
        }
        #[cfg(not(target_endian = "little"))]
        {
            write_frame(w, &Response::encode_targets(block, epoch, trace, timing))
        }
    }

    /// Decode an `OP_TARGETS` frame straight into a caller-owned CSR block
    /// (probabilities from raw bits — bit-identical to [`Response::decode`]),
    /// returning [`RangeFrame::Targets`] with the server's answering epoch.
    /// Any other frame decodes normally and comes back as
    /// [`RangeFrame::Other`] so callers can handle typed error and
    /// `WrongEpoch` frames. This is the zero-allocation receive path of
    /// `serve::ServedReader::read_range_into`.
    pub fn decode_targets_into(
        payload: &[u8],
        out: &mut crate::cache::RangeBlock,
    ) -> io::Result<RangeFrame> {
        let (op, mut c) = open_payload(payload)?;
        if op != OP_TARGETS {
            return Response::decode(payload).map(RangeFrame::Other);
        }
        out.clear();
        let epoch = c.u64()?;
        let (trace, timing) = get_trace_timing(&mut c)?;
        let count = c.u32()? as usize;
        let slots = c.u32()? as usize;
        // saturating sizes: a hostile count/slots makes `take` fail on the
        // (MAX_FRAME-bounded) body instead of overflowing the multiply
        let ids_b = c.take(slots.saturating_mul(4))?;
        let probs_b = c.take(slots.saturating_mul(4))?;
        let offs_b = c.take(count.saturating_add(1).saturating_mul(4))?;
        c.done()?;
        out.ids.extend(ids_b.chunks_exact(4).map(|b| u32::from_le_bytes(b.try_into().unwrap())));
        out.probs.extend(
            probs_b
                .chunks_exact(4)
                .map(|b| f32::from_bits(u32::from_le_bytes(b.try_into().unwrap()))),
        );
        // validate the CSR invariants — first 0, non-decreasing, last ==
        // slots — so a corrupt frame is a typed error, never a block that
        // panics (or lies) on `get`
        let mut prev = 0u32;
        for (i, b) in offs_b.chunks_exact(4).enumerate() {
            let o = u32::from_le_bytes(b.try_into().unwrap());
            if i == 0 {
                if o != 0 {
                    return Err(bad("targets offsets must start at 0"));
                }
                continue; // out.clear() already seeded offsets[0] = 0
            }
            if o < prev || o as usize > slots {
                return Err(bad("targets offsets must be non-decreasing and bounded by slots"));
            }
            out.offsets.push(o);
            prev = o;
        }
        if prev as usize != slots {
            return Err(bad("targets offsets must end at slots"));
        }
        Ok(RangeFrame::Targets { epoch, trace, timing })
    }

    pub fn decode(payload: &[u8]) -> io::Result<Response> {
        let (op, mut c) = open_payload(payload)?;
        let resp = match op {
            OP_TARGETS => {
                let epoch = c.u64()?;
                let (trace, timing) = get_trace_timing(&mut c)?;
                let count = c.u32()? as usize;
                let slots = c.u32()? as usize;
                let ids_b = c.take(slots.saturating_mul(4))?;
                let probs_b = c.take(slots.saturating_mul(4))?;
                let offs_b = c.take(count.saturating_add(1).saturating_mul(4))?;
                let off_at = |i: usize| {
                    u32::from_le_bytes(offs_b[i * 4..i * 4 + 4].try_into().unwrap()) as usize
                };
                if off_at(0) != 0 || off_at(count) != slots {
                    return Err(bad("targets offsets must start at 0 and end at slots"));
                }
                let mut targets = Vec::with_capacity(count.min(1 << 20));
                for i in 0..count {
                    let (lo, hi) = (off_at(i), off_at(i + 1));
                    if lo > hi || hi > slots {
                        return Err(bad(
                            "targets offsets must be non-decreasing and bounded by slots",
                        ));
                    }
                    let ids = ids_b[lo * 4..hi * 4]
                        .chunks_exact(4)
                        .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                        .collect();
                    let probs = probs_b[lo * 4..hi * 4]
                        .chunks_exact(4)
                        .map(|b| f32::from_bits(u32::from_le_bytes(b.try_into().unwrap())))
                        .collect();
                    targets.push(SparseTarget { ids, probs });
                }
                Response::Targets { epoch, trace, timing, targets }
            }
            OP_MANIFEST => {
                let cache_version = c.u32()?;
                let positions = c.u64()?;
                let rounds = c.u32()?;
                let bytes = c.u64()?;
                let shard_count = c.u32()?;
                let kind = match c.u8()? {
                    0 => None,
                    1 => {
                        let n = c.u16()? as usize;
                        let s = std::str::from_utf8(c.take(n)?)
                            .map_err(|_| bad("non-utf8 kind tag"))?;
                        Some(s.to_string())
                    }
                    _ => return Err(bad("bad kind-presence flag")),
                };
                let epoch = c.u64()?;
                Response::Manifest(RemoteManifest {
                    cache_version,
                    positions,
                    rounds,
                    bytes,
                    shard_count,
                    kind,
                    epoch,
                })
            }
            OP_STATS => {
                let requests = c.u64()?;
                let rejected = c.u64()?;
                let errors = c.u64()?;
                let wrong_epoch = c.u64()?;
                let epoch = c.u64()?;
                let shard_loads = c.u64()?;
                let coalesced = c.u64()?;
                let tier = crate::cache::TierCounters {
                    hits: c.u64()?,
                    misses: c.u64()?,
                    backfilled: c.u64()?,
                    origin_computes: c.u64()?,
                };
                let nb = c.u8()? as usize;
                if nb != HIST_BUCKETS {
                    return Err(bad(format!(
                        "stats frame carries {nb} histogram buckets, expected {HIST_BUCKETS}"
                    )));
                }
                let mut hist = Vec::with_capacity(nb);
                for _ in 0..nb {
                    hist.push(c.u64()?);
                }
                let nh = c.u32()? as usize;
                let mut hot = Vec::with_capacity(nh.min(1 << 20));
                for _ in 0..nh {
                    hot.push(c.u64()?);
                }
                let hot_overflow = c.u64()?;
                let deadline_exceeded = c.u64()?;
                let responses_vectored = c.u64()?;
                Response::Stats(StatsSnapshot {
                    requests,
                    rejected,
                    errors,
                    wrong_epoch,
                    epoch,
                    shard_loads,
                    coalesced,
                    tier,
                    hist,
                    hot,
                    hot_overflow,
                    deadline_exceeded,
                    responses_vectored,
                })
            }
            OP_CLUSTER => {
                let n = c.u32()? as usize;
                let text = std::str::from_utf8(c.take(n)?)
                    .map_err(|_| bad("non-utf8 cluster manifest"))?;
                Response::Cluster(ClusterManifest::from_json_str(text).map_err(bad)?)
            }
            OP_METRICS => {
                let n = c.u32()? as usize;
                let text = std::str::from_utf8(c.take(n)?)
                    .map_err(|_| bad("non-utf8 metrics text"))?;
                Response::Metrics(text.to_string())
            }
            OP_TRACE => {
                let count = c.u32()? as usize;
                let mut spans = Vec::with_capacity(count.min(1 << 16));
                for _ in 0..count {
                    let trace = c.u64()?;
                    let kind = SpanKind::from_u8(c.u8()?)
                        .ok_or_else(|| bad("unknown span kind"))?;
                    let member = c.u32()?;
                    let shard = c.u32()?;
                    let start = c.u64()?;
                    let len = c.u32()?;
                    let total_ns = c.u64()?;
                    let mut phases = [0u64; crate::obs::PHASE_COUNT];
                    for ph in phases.iter_mut() {
                        *ph = c.u64()?;
                    }
                    spans.push(Span { trace, kind, member, shard, start, len, total_ns, phases });
                }
                Response::Trace(spans)
            }
            OP_PONG => Response::Pong,
            OP_WRONG_EPOCH => Response::WrongEpoch { epoch: c.u64()? },
            OP_ERROR => {
                let code = ErrCode::from_u16(c.u16()?).unwrap_or(ErrCode::Internal);
                let n = c.u16()? as usize;
                let msg = String::from_utf8_lossy(c.take(n)?).into_owned();
                Response::Error { code, msg }
            }
            other => return Err(bad(format!("unknown response opcode {other:#04x}"))),
        };
        c.done()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    fn roundtrip_resp(r: Response) {
        assert_eq!(Response::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn request_roundtrip() {
        roundtrip_req(Request::GetRange {
            start: 123_456_789,
            len: 512,
            epoch: NO_EPOCH,
            trace: NO_TRACE,
            deadline_us: NO_DEADLINE,
        });
        roundtrip_req(Request::GetRange {
            start: 7,
            len: 1,
            epoch: u64::MAX,
            trace: 0xDEAD_BEEF_CAFE_F00D,
            deadline_us: 250_000,
        });
        roundtrip_req(Request::GetManifest);
        roundtrip_req(Request::GetStats);
        roundtrip_req(Request::GetMetrics);
        roundtrip_req(Request::GetTrace);
        roundtrip_req(Request::GetCluster);
        roundtrip_req(Request::Ping);
    }

    #[test]
    fn targets_roundtrip_bit_exact() {
        let targets = vec![
            SparseTarget { ids: vec![1, 99_999, 131_000], probs: vec![0.4, 0.2, 1e-7] },
            SparseTarget::default(), // empty target (missing position)
            SparseTarget { ids: vec![7], probs: vec![f32::MIN_POSITIVE] },
        ];
        let timing = ServerTiming { queue_ns: 11, decode_ns: 22, origin_ns: 33 };
        let encoded = Response::Targets {
            epoch: 7,
            trace: 0xABCD,
            timing,
            targets: targets.clone(),
        }
        .encode();
        let Response::Targets { epoch, trace, timing: t2, targets: back } =
            Response::decode(&encoded).unwrap()
        else {
            panic!("wrong variant")
        };
        assert_eq!((epoch, trace, t2), (7, 0xABCD, timing));
        assert_eq!(back, targets);
        // bit-exactness, not approximate equality
        assert_eq!(back[2].probs[0].to_bits(), f32::MIN_POSITIVE.to_bits());
    }

    #[test]
    fn encode_targets_matches_response_encode() {
        use crate::cache::RangeBlock;
        let targets = vec![
            SparseTarget { ids: vec![3, 131_000], probs: vec![0.25, f32::MIN_POSITIVE] },
            SparseTarget::default(),
            SparseTarget { ids: vec![9], probs: vec![1e-7] },
        ];
        let mut block = RangeBlock::new();
        for t in &targets {
            block.push_target(t);
        }
        let timing = ServerTiming { queue_ns: 5, decode_ns: 9, origin_ns: 0 };
        for (epoch, trace) in [(NO_EPOCH, NO_TRACE), (3, 0x1234_5678_9ABC_DEF0)] {
            assert_eq!(
                Response::encode_targets(&block, epoch, trace, timing),
                Response::Targets { epoch, trace, timing, targets: targets.clone() }.encode(),
                "block encode must be byte-identical to the Vec<SparseTarget> encode"
            );
        }
    }

    #[test]
    fn decode_targets_into_is_bit_exact_and_passes_other_frames() {
        use crate::cache::RangeBlock;
        let targets = vec![
            SparseTarget { ids: vec![1, 99_999], probs: vec![0.4, f32::MIN_POSITIVE] },
            SparseTarget::default(),
            SparseTarget { ids: vec![7], probs: vec![1e-7] },
        ];
        let timing = ServerTiming { queue_ns: 1, decode_ns: 2, origin_ns: 3 };
        let payload = Response::Targets {
            epoch: 5,
            trace: 0xFEED,
            timing,
            targets: targets.clone(),
        }
        .encode();
        let mut block = RangeBlock::new();
        let RangeFrame::Targets { epoch, trace, timing: t2 } =
            Response::decode_targets_into(&payload, &mut block).unwrap()
        else {
            panic!("expected a decoded Targets frame")
        };
        assert_eq!((epoch, trace, t2), (5, 0xFEED, timing));
        assert_eq!(block.to_targets(), targets);
        let (_, probs0) = block.get(0);
        assert_eq!(probs0[1].to_bits(), f32::MIN_POSITIVE.to_bits());
        // non-Targets frames decode normally and are handed back
        let err = Response::Error { code: ErrCode::Overloaded, msg: "full".into() }.encode();
        let RangeFrame::Other(back) =
            Response::decode_targets_into(&err, &mut block).unwrap()
        else {
            panic!("expected a passed-through frame")
        };
        assert_eq!(back, Response::Error { code: ErrCode::Overloaded, msg: "full".into() });
        // WrongEpoch is a passed-through frame too, not a decode error
        let we = Response::WrongEpoch { epoch: 9 }.encode();
        let RangeFrame::Other(back) = Response::decode_targets_into(&we, &mut block).unwrap()
        else {
            panic!("expected a passed-through frame")
        };
        assert_eq!(back, Response::WrongEpoch { epoch: 9 });
        // trailing garbage in a Targets frame is rejected
        let mut bad = Response::Targets {
            epoch: 5,
            trace: NO_TRACE,
            timing: ServerTiming::default(),
            targets,
        }
        .encode();
        bad.push(0);
        assert!(Response::decode_targets_into(&bad, &mut block).is_err());
    }

    /// `Write` impl that accepts at most 3 bytes per call and never
    /// overrides `write_vectored` — so the default single-buffer vectored
    /// impl plus short writes exercise `write_all_vectored4`'s re-slicing.
    struct TrickleWriter(Vec<u8>);

    impl Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            let n = buf.len().min(3);
            self.0.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_targets_is_byte_identical_to_the_copy_path() {
        use crate::cache::RangeBlock;
        let mut block = RangeBlock::new();
        for t in [
            SparseTarget { ids: vec![1, 99_999, 131_000], probs: vec![0.4, 0.2, 1e-7] },
            SparseTarget::default(),
            SparseTarget { ids: vec![7], probs: vec![f32::MIN_POSITIVE] },
        ] {
            block.push_target(&t);
        }
        let timing = ServerTiming { queue_ns: 11, decode_ns: 22, origin_ns: 33 };
        let mut want = Vec::new();
        write_frame(&mut want, &Response::encode_targets(&block, 7, 0xABCD, timing)).unwrap();
        // a well-behaved writer (Vec) and a pathological one (3 bytes per
        // call, default write_vectored) must both produce the same stream
        let mut got = Vec::new();
        Response::write_targets(&mut got, &block, 7, 0xABCD, timing).unwrap();
        assert_eq!(got, want);
        let mut trickle = TrickleWriter(Vec::new());
        Response::write_targets(&mut trickle, &block, 7, 0xABCD, timing).unwrap();
        assert_eq!(trickle.0, want);
        // empty block: frame is all prefix, still byte-identical
        let empty = RangeBlock::new();
        let mut want = Vec::new();
        write_frame(
            &mut want,
            &Response::encode_targets(&empty, NO_EPOCH, NO_TRACE, ServerTiming::default()),
        )
        .unwrap();
        let mut got = Vec::new();
        Response::write_targets(&mut got, &empty, NO_EPOCH, NO_TRACE, ServerTiming::default())
            .unwrap();
        assert_eq!(got, want);
        assert_eq!(got.len(), TARGETS_PREFIX_BYTES + 4, "prefix + the lone offsets[0] entry");
    }

    #[test]
    fn targets_decode_rejects_broken_csr_offsets() {
        use crate::cache::RangeBlock;
        let mut block = RangeBlock::new();
        block.push_slot(1, 0.5);
        block.push_slot(2, 0.25);
        block.end_position();
        block.push_slot(3, 0.125);
        block.end_position();
        let good = Response::encode_targets(&block, 1, NO_TRACE, ServerTiming::default());
        // offsets live in the last (count+1)*4 bytes; corrupt each entry in
        // turn and expect a typed decode error from both decode paths
        let offs_at = good.len() - 3 * 4;
        let mut scratch = RangeBlock::new();
        for (entry, val) in [(0usize, 1u32), (1, 9), (2, 1), (2, 9)] {
            let mut bad = good.clone();
            bad[offs_at + entry * 4..offs_at + entry * 4 + 4]
                .copy_from_slice(&val.to_le_bytes());
            assert!(
                Response::decode(&bad).is_err(),
                "decode accepted offsets[{entry}] = {val}"
            );
            assert!(
                Response::decode_targets_into(&bad, &mut scratch).is_err(),
                "decode_targets_into accepted offsets[{entry}] = {val}"
            );
        }
        // a lying slots field shifts every section: typed error, not junk
        let mut bad = good.clone();
        bad[TARGETS_PREFIX_BYTES - 4..TARGETS_PREFIX_BYTES]
            .copy_from_slice(&9u32.to_le_bytes());
        assert!(Response::decode(&bad).is_err());
        assert!(Response::decode_targets_into(&bad, &mut scratch).is_err());
        // the good frame still decodes after all that
        let RangeFrame::Targets { .. } =
            Response::decode_targets_into(&good, &mut scratch).unwrap()
        else {
            panic!("expected Targets")
        };
        assert_eq!(scratch.to_targets(), block.to_targets());
    }

    #[test]
    fn manifest_roundtrip_with_and_without_kind() {
        roundtrip_resp(Response::Manifest(RemoteManifest {
            cache_version: 2,
            positions: 16_384,
            rounds: 50,
            bytes: 2_473_917,
            shard_count: 4,
            kind: Some("rs:rounds=50,temp=1".into()),
            epoch: 12,
        }));
        roundtrip_resp(Response::Manifest(RemoteManifest {
            cache_version: 1,
            positions: 10,
            rounds: 0,
            bytes: 100,
            shard_count: 1,
            kind: None,
            epoch: NO_EPOCH,
        }));
    }

    #[test]
    fn wrong_epoch_roundtrip() {
        roundtrip_resp(Response::WrongEpoch { epoch: 1 });
        roundtrip_resp(Response::WrongEpoch { epoch: u64::MAX });
    }

    #[test]
    fn cluster_manifest_roundtrip() {
        use crate::cluster::{ClusterManifest, ShardSpec};
        use crate::serve::Endpoint;
        let m = ClusterManifest::new(
            3,
            vec![
                ShardSpec {
                    lo: 0,
                    hi: 1024,
                    endpoints: vec![
                        Endpoint::parse("unix:///tmp/a.sock").unwrap(),
                        Endpoint::parse("tcp://127.0.0.1:7401").unwrap(),
                    ],
                },
                ShardSpec {
                    lo: 1024,
                    hi: 4096,
                    endpoints: vec![Endpoint::parse("tcp://127.0.0.1:7402").unwrap()],
                },
            ],
        )
        .unwrap();
        roundtrip_resp(Response::Cluster(m));
    }

    #[test]
    fn remote_manifest_kind_matches_reader_rules() {
        use crate::spec::CacheKind;
        let m = |kind: Option<&str>, rounds| RemoteManifest {
            cache_version: 2,
            positions: 1,
            rounds,
            bytes: 1,
            shard_count: 1,
            kind: kind.map(|s| s.to_string()),
            epoch: NO_EPOCH,
        };
        assert_eq!(
            m(Some("rs:rounds=50,temp=0.8"), 0).cache_kind().unwrap(),
            CacheKind::Rs { rounds: 50, temp: 0.8 }
        );
        assert_eq!(m(None, 50).cache_kind().unwrap(), CacheKind::Rs { rounds: 50, temp: 1.0 });
        assert_eq!(m(None, 0).cache_kind().unwrap(), CacheKind::TopK);
        assert!(m(Some("hologram:q=3"), 0).cache_kind().is_err());
    }

    #[test]
    fn stats_roundtrip() {
        roundtrip_resp(Response::Stats(StatsSnapshot {
            requests: 100,
            rejected: 3,
            errors: 1,
            wrong_epoch: 2,
            epoch: 4,
            shard_loads: 8,
            coalesced: 5,
            tier: crate::cache::TierCounters {
                hits: 90,
                misses: 10,
                backfilled: 4096,
                origin_computes: 7,
            },
            hist: (0..HIST_BUCKETS as u64).collect(),
            hot: vec![40, 0, 60],
            hot_overflow: 2,
            deadline_exceeded: 6,
            responses_vectored: 93,
        }));
    }

    #[test]
    fn metrics_roundtrip() {
        roundtrip_resp(Response::Metrics(String::new()));
        roundtrip_resp(Response::Metrics(
            "# TYPE rskd_serve_requests_total counter\nrskd_serve_requests_total 42\n".into(),
        ));
        // non-utf8 body is rejected
        let mut p = preamble(OP_METRICS);
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Response::decode(&p).is_err());
    }

    #[test]
    fn trace_roundtrip() {
        use crate::obs::PHASE_COUNT;
        roundtrip_resp(Response::Trace(Vec::new()));
        roundtrip_resp(Response::Trace(vec![
            Span {
                trace: 0x1111_2222_3333_4444,
                kind: SpanKind::Root,
                member: 0,
                shard: u32::MAX,
                start: 9_000,
                len: 256,
                total_ns: 1_234_567,
                phases: [0, 0, 0, 1_000],
            },
            Span {
                trace: 0x1111_2222_3333_4444,
                kind: SpanKind::Segment,
                member: 2,
                shard: 7,
                start: 9_000,
                len: 128,
                total_ns: 600_000,
                phases: [10, 20, 30, 40],
            },
            Span {
                trace: 5,
                kind: SpanKind::Server,
                member: 0,
                shard: 3,
                start: 0,
                len: 1,
                total_ns: 0,
                phases: [0; PHASE_COUNT],
            },
        ]));
        // unknown span kind byte is a decode error
        let mut p = preamble(OP_TRACE);
        p.extend_from_slice(&1u32.to_le_bytes());
        p.extend_from_slice(&7u64.to_le_bytes());
        p.push(99); // bad kind
        p.extend_from_slice(&[0u8; 4 + 4 + 8 + 4 + 8 + 8 * PHASE_COUNT]);
        assert!(Response::decode(&p).is_err());
    }

    #[test]
    fn error_roundtrip_and_unknown_code() {
        roundtrip_resp(Response::Error { code: ErrCode::Overloaded, msg: "queue full".into() });
        roundtrip_resp(Response::Error {
            code: ErrCode::DeadlineExceeded,
            msg: "expired in queue".into(),
        });
        assert_eq!(ErrCode::from_u16(6), Some(ErrCode::DeadlineExceeded));
        // unknown code bytes decode to Internal rather than failing
        let mut p = preamble(OP_ERROR);
        p.extend_from_slice(&999u16.to_le_bytes());
        p.extend_from_slice(&2u16.to_le_bytes());
        p.extend_from_slice(b"xy");
        let Response::Error { code, msg } = Response::decode(&p).unwrap() else { panic!() };
        assert_eq!(code, ErrCode::Internal);
        assert_eq!(msg, "xy");
    }

    #[test]
    fn frame_io_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Request::Ping.encode()).unwrap();
        write_frame(&mut buf, &Request::GetManifest.encode()).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(Request::decode(&read_frame(&mut r).unwrap().unwrap()).unwrap(), Request::Ping);
        assert_eq!(
            Request::decode(&read_frame(&mut r).unwrap().unwrap()).unwrap(),
            Request::GetManifest
        );
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at frame boundary");
    }

    #[test]
    fn frame_rejects_oversize_and_truncation() {
        // oversize length prefix
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // EOF mid-payload
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]); // 3 of 8 bytes
        assert!(read_frame(&mut buf.as_slice()).is_err());
        // EOF mid-length-prefix
        assert!(read_frame(&mut [0u8, 0].as_slice()).is_err());
    }

    #[test]
    fn version_and_opcode_validation() {
        let mut p = Request::Ping.encode();
        p[0] = 99;
        let err = Request::decode(&p).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
        let p = vec![PROTOCOL_VERSION, 0x7F];
        assert!(Request::decode(&p).is_err());
        // trailing garbage is rejected, not ignored
        let mut p = Request::GetManifest.encode();
        p.push(0);
        assert!(Request::decode(&p).is_err());
    }
}
