//! Model state handles: the flat-parameter convention means a model is four
//! arrays (params, adam m, adam v, step) plus its role name. Training graphs
//! take and return these; the coordinator never inspects parameter layout.

use anyhow::Result;

use crate::runtime::{Engine, HostTensor};

#[derive(Clone, Debug)]
pub struct ModelState {
    pub role: String,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl ModelState {
    /// Initialize from the model's `init_<role>` graph.
    pub fn init(engine: &Engine, role: &str, seed: i32) -> Result<ModelState> {
        let out = engine.call(&format!("init_{role}"), &[HostTensor::scalar_i32(seed)])?;
        let params = out.into_iter().next().unwrap().into_f32()?;
        let n = params.len();
        Ok(ModelState { role: role.to_string(), params, m: vec![0.0; n], v: vec![0.0; n], step: 0 })
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Pack optimizer state as graph inputs (params, m, v, step).
    pub fn opt_inputs(&self) -> [HostTensor; 4] {
        let n = self.params.len();
        [
            HostTensor::f32(self.params.clone(), &[n]),
            HostTensor::f32(self.m.clone(), &[n]),
            HostTensor::f32(self.v.clone(), &[n]),
            HostTensor::scalar_i32(self.step),
        ]
    }

    /// Absorb the (params', m', v', step') prefix of a train-graph result.
    pub fn absorb(&mut self, outs: &mut Vec<HostTensor>) -> Result<()> {
        let step = outs.remove(3);
        let v = outs.remove(2);
        let m = outs.remove(1);
        let p = outs.remove(0);
        self.params = p.into_f32()?;
        self.m = m.into_f32()?;
        self.v = v.into_f32()?;
        self.step = step.as_i32()?[0];
        Ok(())
    }

    /// Fresh optimizer state (for fine-tuning stages).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    pub fn params_tensor(&self) -> HostTensor {
        HostTensor::f32(self.params.clone(), &[self.params.len()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_consumes_prefix() {
        let mut st = ModelState {
            role: "t".into(),
            params: vec![0.0; 3],
            m: vec![0.0; 3],
            v: vec![0.0; 3],
            step: 0,
        };
        let mut outs = vec![
            HostTensor::f32(vec![1.0, 2.0, 3.0], &[3]),
            HostTensor::f32(vec![4.0, 5.0, 6.0], &[3]),
            HostTensor::f32(vec![7.0, 8.0, 9.0], &[3]),
            HostTensor::scalar_i32(5),
            HostTensor::scalar_f32(2.5), // loss stays behind
        ];
        st.absorb(&mut outs).unwrap();
        assert_eq!(st.params, vec![1.0, 2.0, 3.0]);
        assert_eq!(st.step, 5);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].scalar().unwrap(), 2.5);
    }
}
