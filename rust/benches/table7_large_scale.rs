//! Table 7: large-scale comparison (paper: 8B→3B, 100B tokens; here the
//! `large` artifact config) — CE, Top-K 12/50, RS-KD 12, RS+adaptive, FullKD,
//! with 0-shot before and after instruction SFT. Requires
//! `make artifacts-large`; falls back to the small config with a note.

use rskd::coordinator::trainer::{AdaptiveLr, SparseVariant};
use rskd::coordinator::{CacheKind, Pipeline, StudentMethod};
use rskd::data::TextDataset;
use rskd::expt;
use rskd::report::Report;

fn main() {
    let (dir, tag) = if expt::artifacts_exist("artifacts/large") {
        ("artifacts/large", "large")
    } else if expt::artifacts_exist("artifacts/small") {
        println!("[artifacts/large missing: running the scaled-down analogue on artifacts/small]");
        ("artifacts/small", "small-as-large")
    } else {
        println!("[skipped: no artifacts]");
        return;
    };
    let cfg = expt::config_for(dir, "table7");
    let pipe = Pipeline::prepare(cfg).unwrap();
    let (tk_cache, _) = pipe.build_cache(CacheKind::TopK, "t7-tk", 1).unwrap();
    let (rs_cache, _) = pipe.build_cache(CacheKind::Rs { rounds: 12, temp: 1.0 }, "t7-rs", 2).unwrap();

    // instruction SFT set in the corpus grammar (paper: Tulu)
    let ds = TextDataset::build(&pipe.cfg.corpus, pipe.engine.manifest().vocab, 4_000, 5);
    let sft_docs = TextDataset::build_sft_docs(&pipe.cfg.corpus, &ds.bpe, 60, 6);

    let adaptive = Some(AdaptiveLr { ratio: 2.0, hard_frac: 0.5 });
    let runs: Vec<(&str, StudentMethod, Option<&rskd::cache::CacheReader>)> = vec![
        ("CE", StudentMethod::Ce, None),
        ("Top-K 12",
         StudentMethod::Sparse { variant: SparseVariant::TopK { k: 12, normalize: false }, alpha: 0.0, adaptive: None },
         Some(&tk_cache)),
        ("Top-K 50",
         StudentMethod::Sparse { variant: SparseVariant::TopK { k: 50, normalize: false }, alpha: 0.0, adaptive: None },
         Some(&tk_cache)),
        ("Ours (12)", expt::rs(), Some(&rs_cache)),
        ("Ours (12)+",
         StudentMethod::Sparse { variant: SparseVariant::Rs, alpha: 0.1, adaptive },
         Some(&rs_cache)),
        ("FullKD", StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None),
    ];

    let mut report = Report::new("table7_large_scale", format!("Large-scale sparse KD ({tag}) — paper Table 7").as_str());
    let mut rows = Vec::new();
    for (name, method, cache) in runs {
        let (mut student, _, ev, z) = expt::run_with_zero_shot(&pipe, &method, cache, 3).unwrap();
        // IF SFT: fine-tune on instructions, re-score
        student.reset_optimizer();
        pipe.continue_ce(&mut student, &sft_docs, 25, 2e-5).unwrap();
        let z_sft = expt::zero_shot(&pipe, &student).unwrap();
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            format!("{z:.1}"),
            format!("{z_sft:.1}"),
        ]);
    }
    report.table(&["Method", "LM Loss", "ECE %", "SpecAccept %", "0-shot", "IF SFT 0-shot"], &rows);
    report.finish();
}
