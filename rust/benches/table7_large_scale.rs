//! Table 7: large-scale comparison (paper: 8B→3B, 100B tokens; here the
//! `large` artifact config) — CE, Top-K 12/50, RS-KD 12, RS+adaptive, FullKD,
//! with 0-shot before and after instruction SFT. Requires
//! `make artifacts-large`; falls back to the small config with a note.

use rskd::coordinator::Pipeline;
use rskd::data::TextDataset;
use rskd::expt;
use rskd::report::Report;

fn main() {
    let (dir, tag) = if expt::artifacts_exist("artifacts/large") {
        ("artifacts/large", "large")
    } else if expt::artifacts_exist("artifacts/small") {
        println!("[artifacts/large missing: running the scaled-down analogue on artifacts/small]");
        ("artifacts/small", "small-as-large")
    } else {
        println!("[skipped: no artifacts]");
        return;
    };
    let cfg = expt::config_for(dir, "table7");
    let mut pipe = Pipeline::prepare(cfg).unwrap();

    // instruction SFT set in the corpus grammar (paper: Tulu)
    let ds = TextDataset::build(&pipe.cfg.corpus, pipe.engine.manifest().vocab, 4_000, 5);
    let sft_docs = TextDataset::build_sft_docs(&pipe.cfg.corpus, &ds.bpe, 60, 6);

    let runs: Vec<(&str, &str)> = vec![
        ("CE", "ce"),
        ("Top-K 12", "topk:k=12"),
        ("Top-K 50", "topk:k=50"),
        ("Ours (12)", "rs:rounds=12"),
        ("Ours (12)+", "rs:rounds=12,alpha=0.1,adapt=2@0.5"),
        ("FullKD", "fullkd"),
    ];

    let mut report = Report::new("table7_large_scale", format!("Large-scale sparse KD ({tag}) — paper Table 7").as_str());
    let mut rows = Vec::new();
    for (name, s) in runs {
        let (mut student, _, ev, z) =
            expt::run_with_zero_shot(&mut pipe, &expt::spec(s), 3).unwrap();
        // IF SFT: fine-tune on instructions, re-score
        student.reset_optimizer();
        pipe.continue_ce(&mut student, &sft_docs, 25, 2e-5).unwrap();
        let z_sft = expt::zero_shot(&pipe, &student).unwrap();
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            format!("{z:.1}"),
            format!("{z_sft:.1}"),
        ]);
    }
    report.table(&["Method", "LM Loss", "ECE %", "SpecAccept %", "0-shot", "IF SFT 0-shot"], &rows);
    report.finish();
}
