//! Table 2: naive fixes for Top-K KD — label smoothing, ghost token, and
//! residual-to-ground-truth ("Naive Fix") at several K, with CE/FullKD
//! anchors. Expectation: smoothing fixes ECE but hurts loss; ghost improves
//! both; naive fix approaches FullKD as K grows.

use rskd::coordinator::pct_ce_to_fullkd;
use rskd::expt;
use rskd::report::{Report, METRIC_HEADER};

fn main() {
    let Some(mut pipe) = expt::prepare_small("table2") else { return };

    let mut report = Report::new("table2_fixes", "Naive fixes for Top-K KD (paper Table 2)");
    let (_, _, ev_ce, z_ce) = expt::run_with_zero_shot(&mut pipe, &expt::spec("ce"), 3).unwrap();
    let (_, _, ev_fk, z_fk) =
        expt::run_with_zero_shot(&mut pipe, &expt::spec("fullkd"), 3).unwrap();

    let mut rows = Vec::new();
    let mut push = |name: String, ev: &rskd::coordinator::EvalResult, z: f64,
                    rows: &mut Vec<Vec<String>>| {
        rows.push(vec![
            name,
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            format!("{:.0}%", pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)),
            format!("{z:.1}"),
        ]);
    };
    push("CE".into(), &ev_ce, z_ce, &mut rows);

    // every fix shares the one Top-K cache (same cache plan, memoized)
    for (name, s) in [
        ("Smoothing 50", "smooth:k=50"),
        ("Ghost Token 50", "ghost:k=50"),
        ("NaiveFix 1", "naive:k=1"),
        ("NaiveFix 5", "naive:k=5"),
        ("NaiveFix 10", "naive:k=10"),
        ("NaiveFix 20", "naive:k=20"),
        ("NaiveFix 50", "naive:k=50"),
    ] {
        let (_, _, ev, z) = expt::run_with_zero_shot(&mut pipe, &expt::spec(s), 3).unwrap();
        push(name.into(), &ev, z, &mut rows);
    }
    push("FullKD".into(), &ev_fk, z_fk, &mut rows);
    report.table(&METRIC_HEADER, &rows);
    report.finish();
}
