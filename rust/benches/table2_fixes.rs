//! Table 2: naive fixes for Top-K KD — label smoothing, ghost token, and
//! residual-to-ground-truth ("Naive Fix") at several K, with CE/FullKD
//! anchors. Expectation: smoothing fixes ECE but hurts loss; ghost improves
//! both; naive fix approaches FullKD as K grows.

use rskd::coordinator::trainer::SparseVariant;
use rskd::coordinator::{pct_ce_to_fullkd, CacheKind, StudentMethod};
use rskd::expt;
use rskd::report::{Report, METRIC_HEADER};

fn sparse(variant: SparseVariant) -> StudentMethod {
    StudentMethod::Sparse { variant, alpha: 0.0, adaptive: None }
}

fn main() {
    let Some(pipe) = expt::prepare_small("table2") else { return };
    let (cache, _) = pipe.build_cache(CacheKind::TopK, "t2", 1).unwrap();

    let mut report = Report::new("table2_fixes", "Naive fixes for Top-K KD (paper Table 2)");
    let (_, _, ev_ce, z_ce) = expt::run_with_zero_shot(&pipe, &StudentMethod::Ce, None, 3).unwrap();
    let (_, _, ev_fk, z_fk) = expt::run_with_zero_shot(
        &pipe, &StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None, 3).unwrap();

    let mut rows = Vec::new();
    let mut push = |name: String, ev: &rskd::coordinator::EvalResult, z: f64,
                    rows: &mut Vec<Vec<String>>| {
        rows.push(vec![
            name,
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            format!("{:.0}%", pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)),
            format!("{z:.1}"),
        ]);
    };
    push("CE".into(), &ev_ce, z_ce, &mut rows);

    for (name, variant) in [
        ("Smoothing 50", SparseVariant::Smoothing { k: 50 }),
        ("Ghost Token 50", SparseVariant::GhostToken { k: 50 }),
        ("NaiveFix 1", SparseVariant::NaiveFix { k: 1 }),
        ("NaiveFix 5", SparseVariant::NaiveFix { k: 5 }),
        ("NaiveFix 10", SparseVariant::NaiveFix { k: 10 }),
        ("NaiveFix 20", SparseVariant::NaiveFix { k: 20 }),
        ("NaiveFix 50", SparseVariant::NaiveFix { k: 50 }),
    ] {
        let (_, _, ev, z) = expt::run_with_zero_shot(&pipe, &sparse(variant), Some(&cache), 3).unwrap();
        push(name.into(), &ev, z, &mut rows);
    }
    push("FullKD".into(), &ev_fk, z_fk, &mut rows);
    report.table(&METRIC_HEADER, &rows);
    report.finish();
}
