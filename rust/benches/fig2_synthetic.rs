//! Figure 2: synthetic examples.
//!  2a — Zipf toy distribution: effective targets of Top-K / Naive Fix / RS
//!       vs ground truth (head values + bias L1).
//!  2b — Gaussian-cluster MLP calibration under CE/FullKD/Top-K/RS-KD.
//!  2c — CIFAR-100-like toy image calibration (same protocol).

use rskd::report::Report;
use rskd::sampling::zipf::{averaged_effective_target, bias_l1, zipf};
use rskd::spec::{DistillSpec, Variant};
use rskd::toynn::train::train_teacher;
use rskd::toynn::{train_toy, GaussianClasses, ToyImages, ToyMethod, ToyTrainConfig};

fn fig2a(report: &mut Report) {
    report.line("--- Fig 2a: Zipf toy distribution (head estimates + bias) ---");
    let p = zipf(100_000, 1.0);
    let methods: [(&str, Option<DistillSpec>); 4] = [
        ("Ground Truth", None),
        (
            "Top-K 20 (renorm)",
            Some(DistillSpec::sparse(Variant::TopK { k: 20, normalize: true })),
        ),
        ("Naive Fix 20", Some(DistillSpec::sparse(Variant::NaiveFix { k: 20 }))),
        ("RS (22 samples)", Some(DistillSpec::rs(22))),
    ];
    let mut rows = Vec::new();
    for (name, spec) in methods {
        let head = match &spec {
            None => p[..6].to_vec(),
            Some(s) => averaged_effective_target(&p, s, 400, 6, 0),
        };
        let bias = spec.as_ref().map(|s| bias_l1(&p, s, 400, 0));
        let mut row = vec![name.to_string()];
        row.extend(head.iter().map(|x| format!("{x:.4}")));
        row.push(bias.map(|b| format!("{b:.4}")).unwrap_or_else(|| "0".into()));
        rows.push(row);
    }
    report.table(&["series", "p1", "p2", "p3", "p4", "p5", "p6", "bias L1"], &rows);
}

fn toy_block(report: &mut Report, title: &str, dim: usize, classes: usize,
             mut sample: impl FnMut(usize, &mut rskd::util::rng::Pcg) -> (Vec<f32>, Vec<u32>)) {
    report.line(format!("--- {title} ---"));
    let cfg = ToyTrainConfig { steps: 500, ..Default::default() };
    let teacher = train_teacher(&mut sample, dim, classes, &cfg);
    let mut rows = Vec::new();
    for m in [
        ToyMethod::Ce,
        ToyMethod::FullKd,
        ToyMethod::TopK { k: 7 },
        ToyMethod::RandomSampling { rounds: 50 },
    ] {
        let res = train_toy(&mut sample, dim, classes, Some(&teacher), m, &cfg);
        rows.push(vec![
            m.name().to_string(),
            format!("{:.1}", res.accuracy * 100.0),
            format!("{:.1}", res.calibration.ece * 100.0),
            format!("{:+.3}", res.calibration.mean_conf - res.calibration.accuracy),
        ]);
    }
    report.table(&["method", "acc %", "ECE %", "overconfidence"], &rows);
}

fn main() {
    let mut report = Report::new("fig2_synthetic", "Synthetic examples (paper Figure 2)");
    fig2a(&mut report);
    let gauss = GaussianClasses::new(128, 64, 1.5, 0);
    toy_block(&mut report, "Fig 2b: Gaussian-cluster MLP calibration", 64, 128,
              |b, r| gauss.batch(b, r));
    let imgs = ToyImages::new(64, 8, 0);
    let dim = imgs.dim();
    toy_block(&mut report, "Fig 2c: toy image (CIFAR-100 stand-in) calibration", dim, 64,
              |b, r| imgs.batch(b, 0.6, r));
    report.finish();
}
