//! Perf harness (EXPERIMENTS.md §Perf): per-layer hot-path timings.
//!  L1 vs L2 — Pallas sparse-KLD train step vs pure-jnp variant (identical
//!             numerics, different lowering).
//!  L3       — cache block assembly, RS sampling (pure rust vs graph),
//!             host<->device transfer share from engine stats.

use std::time::Duration;

use rskd::cache::CacheReader;
use rskd::coordinator::trainer::{assemble_sparse_block, SparseVariant};
use rskd::coordinator::{CacheKind, Pipeline};
use rskd::expt;
use rskd::report::Report;
use rskd::runtime::HostTensor;
use rskd::util::bench::bench;
use rskd::util::rng::Pcg;

fn main() {
    if !expt::artifacts_exist("artifacts/small") {
        println!("[skipped: artifacts/small missing]");
        return;
    }
    let mut cfg = expt::config_for("artifacts/small", "perf");
    cfg.teacher_steps = 40; // perf pass does not need a good teacher
    let pipe = Pipeline::prepare(cfg).unwrap();
    let m = pipe.engine.manifest();
    let (b, s, v, k) = (m.batch, m.seq, m.vocab, m.k_slots);
    let (cache, _) = pipe.build_cache(CacheKind::Rs { rounds: 50, temp: 1.0 }, "perf", 1).unwrap();

    let mut report = Report::new("perf_hotpath", "Hot-path timings per layer");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let budget = Duration::from_millis(2500);

    // --- L3: batch assembly from cache (host) ---
    let mut loader = pipe.packed_loader(11, false, 0);
    let batch = loader.next_batch();
    let st = bench(2, budget, || {
        let blk = assemble_sparse_block(&cache, &batch, v, k, SparseVariant::Rs, None);
        std::hint::black_box(blk.val.len());
    });
    rows.push(vec!["L3 cache->block assembly".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L3: pure-rust RS sampling of one [B,S] block of teacher rows ---
    let probs = pipe
        .engine
        .call("fwd_teacher", &[pipe.teacher.params_tensor(),
                               HostTensor::i32(batch.tokens.clone(), &[b, s])])
        .unwrap()
        .remove(0);
    let pv = probs.as_f32().unwrap().to_vec();
    let st = bench(1, budget, || {
        let mut rng = Pcg::new(1);
        let mut acc = 0usize;
        for row in pv.chunks(v) {
            acc += rskd::sampling::random_sampling(row, 50, 1.0, &mut rng).k();
        }
        std::hint::black_box(acc);
    });
    rows.push(vec!["L3 rust RS sampler (B*S rows)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 sampler graph for the same block ---
    pipe.engine.warmup(&["sample_rs", "train_sparse_student", "train_sparse_jnp_student"]).unwrap();
    let n = m.n_rounds;
    let mut unif = vec![0.0f32; b * s * n];
    Pcg::new(2).fill_f32(&mut unif);
    let st = bench(2, budget, || {
        let out = pipe
            .engine
            .call("sample_rs", &[probs.clone(), HostTensor::f32(unif.clone(), &[b, s, n]),
                                 HostTensor::scalar_f32(1.0)])
            .unwrap();
        std::hint::black_box(out.len());
    });
    rows.push(vec!["L1 sample_rs graph (incl. transfer)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 vs L2: pallas vs jnp sparse train step ---
    let student = rskd::model::ModelState::init(&pipe.engine, "student", 1).unwrap();
    let blk = assemble_sparse_block(&cache, &batch, v, k, SparseVariant::Rs, None);
    let mk_args = || {
        let [p, mm, vv, stp] = student.opt_inputs();
        vec![
            p, mm, vv, stp,
            HostTensor::scalar_f32(1e-4),
            HostTensor::i32(batch.tokens.clone(), &[b, s]),
            HostTensor::i32(batch.labels.clone(), &[b, s]),
            HostTensor::i32(blk.idx.clone(), &[b, s, k]),
            HostTensor::f32(blk.val.clone(), &[b, s, k]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.smooth.clone(), &[b, s]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.lr_scale.clone(), &[b, s]),
        ]
    };
    for (label, graph) in [
        ("L1 train_sparse (pallas kernel)", "train_sparse_student"),
        ("L2 train_sparse_jnp (pure jnp)", "train_sparse_jnp_student"),
    ] {
        let args = mk_args();
        let st = bench(2, budget, || {
            let out = pipe.engine.call(graph, &args).unwrap();
            std::hint::black_box(out.len());
        });
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    // --- baseline steps for context ---
    for (label, graph, extra) in [
        ("train_ce step", "train_ce_student", 0usize),
        ("fwd_teacher", "fwd_teacher", 1),
    ] {
        let st = match extra {
            0 => {
                let [p, mm, vv, stp] = student.opt_inputs();
                let args = vec![p, mm, vv, stp, HostTensor::scalar_f32(1e-4),
                                HostTensor::i32(batch.tokens.clone(), &[b, s]),
                                HostTensor::i32(batch.labels.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
            _ => {
                let args = vec![pipe.teacher.params_tensor(),
                                HostTensor::i32(batch.tokens.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
        };
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    report.table(&["hot path", "median"], &rows);
    let es = pipe.engine.stats();
    report.line(format!(
        "engine totals: {} execs, exec {:.2}s, transfer {:.2}s ({:.0}% of exec+transfer)",
        es.executions,
        es.execute_time.as_secs_f64(),
        es.transfer_time.as_secs_f64(),
        100.0 * es.transfer_time.as_secs_f64()
            / (es.execute_time + es.transfer_time).as_secs_f64().max(1e-9)
    ));
    let _unused: Option<&CacheReader> = None;
    report.finish();
}
