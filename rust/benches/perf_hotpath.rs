//! Perf harness (EXPERIMENTS.md §Perf): per-layer hot-path timings.
//!  L1 vs L2 — Pallas sparse-KLD train step vs pure-jnp variant (identical
//!             numerics, different lowering).
//!  L3       — cache build throughput (1 vs N producers through the
//!             out-of-order writer), cold/warm lazy reads, cache block
//!             assembly, RS sampling (pure rust vs graph), host<->device
//!             transfer share from engine stats.
//!  serve    — loopback round-trip overhead of the sparse-logit server vs a
//!             direct reader call, and a 4-client concurrent burst with
//!             server-side p50/p99 (the `load-gen` subcommand is the
//!             heavier, configurable version of this section).
//!
//! The cache-layer and serve sections are host-only and run even when
//! `artifacts/` is missing, so the storage + serving hot paths are
//! benchmarkable on any machine.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rskd::cache::quant::ProbCodec;
use rskd::cache::{CacheReader, CacheWriter, SparseTarget};
use rskd::coordinator::{assemble_sparse_block, Pipeline};
use rskd::expt;
use rskd::report::Report;
use rskd::runtime::HostTensor;
use rskd::sampling::random_sampling;
use rskd::sampling::zipf::zipf;
use rskd::serve::{Endpoint, ServeClient, ServeConfig, Server};
use rskd::spec::Variant;
use rskd::util::bench::bench;
use rskd::util::rng::Pcg;

/// Build an `n`-position cache with `producers` concurrent pushers (strided
/// interleave, so every shard sees every producer) and return positions/sec.
fn bench_cache_build(targets: &[SparseTarget], producers: usize, dir: &std::path::Path) -> f64 {
    let _ = std::fs::remove_dir_all(dir);
    let t0 = Instant::now();
    let w = CacheWriter::create(dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    std::thread::scope(|s| {
        for p in 0..producers {
            let w = &w;
            s.spawn(move || {
                for pos in (p..targets.len()).step_by(producers) {
                    assert!(w.push(pos as u64, targets[pos].clone()));
                }
            });
        }
    });
    let stats = w.finish().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(stats.positions as usize, targets.len());
    targets.len() as f64 / dt
}

fn cache_layer_benches(report: &mut Report) {
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(7);
    let n_positions = 16_384usize;
    let targets: Vec<SparseTarget> =
        (0..n_positions).map(|_| random_sampling(&p, 50, 1.0, &mut rng)).collect();
    let dir = std::env::temp_dir().join(format!("rskd-perf-cache-{}", std::process::id()));

    report.line("--- L3 cache build throughput (out-of-order writer, RS-50 targets) ---");
    let mut rows: Vec<Vec<String>> = Vec::new();
    // the last iteration leaves the 32-shard cache on disk for the read benches
    for producers in [1usize, 2, 4] {
        let pps = bench_cache_build(&targets, producers, &dir);
        rows.push(vec![
            format!("build, {producers} producer(s)"),
            format!("{:.0} positions/s", pps),
        ]);
    }
    report.table(&["cache build", "throughput"], &rows);

    let budget = Duration::from_millis(800);
    let mut rows: Vec<Vec<String>> = Vec::new();

    // cold open: metadata only (v1 decoded every shard here)
    let st = bench(1, budget, || {
        let r = CacheReader::open(&dir).unwrap();
        std::hint::black_box(r.shard_count());
    });
    rows.push(vec!["open (lazy, manifest only)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // cold read: every iteration reopens, so the first range decodes a shard
    let st = bench(1, budget, || {
        let r = CacheReader::open(&dir).unwrap();
        std::hint::black_box(r.get_range(4096, 512).len());
    });
    rows.push(vec!["cold get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // warm read: LRU hit path
    let r = CacheReader::open(&dir).unwrap();
    let _ = r.get_range(4096, 512);
    let st = bench(2, budget, || {
        std::hint::black_box(r.get_range(4096, 512).len());
    });
    rows.push(vec!["warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // full sequential sweep through a capacity-4 LRU (forced eviction churn)
    let st = bench(1, budget, || {
        let r = CacheReader::open_with_capacity(&dir, 4).unwrap();
        let mut acc = 0usize;
        for start in (0..n_positions as u64).step_by(512) {
            acc += r.get_range(start, 512).len();
        }
        std::hint::black_box(acc);
    });
    rows.push(vec![
        format!("sweep {n_positions} positions, LRU cap 4"),
        format!("{:.3} ms", st.per_iter_ms()),
    ]);
    report.table(&["cache read (lazy LRU reader)", "median"], &rows);
    report.line(format!(
        "cache on disk: {} shards, resident after warm range: {} shard(s)",
        r.shard_count(),
        r.resident_shards()
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Serving layer: wire round-trip vs direct reader, then a 4-client burst.
fn serve_layer_benches(report: &mut Report) {
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(11);
    let n_positions = 8192u64;
    let dir = std::env::temp_dir().join(format!("rskd-perf-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();

    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let ep = Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0)));
    let server = Server::start(Arc::clone(&reader), ep, ServeConfig::default()).unwrap();
    let endpoint = server.endpoint().clone();

    report.line("--- serve: loopback TCP server over the same cache ---");
    let budget = Duration::from_millis(800);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let direct = CacheReader::open(&dir).unwrap();
    let _ = direct.get_range(2048, 512); // warm the shard
    let st = bench(2, budget, || {
        std::hint::black_box(direct.get_range(2048, 512).len());
    });
    rows.push(vec!["direct warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);
    let mut client = ServeClient::connect(&endpoint).unwrap();
    let _ = client.get_range(2048, 512).unwrap();
    let st = bench(2, budget, || {
        std::hint::black_box(client.get_range(2048, 512).unwrap().len());
    });
    rows.push(vec!["served warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // 4 concurrent clients sweeping overlapping ranges
    let t0 = Instant::now();
    let per_client = 64usize;
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let endpoint = &endpoint;
            s.spawn(move || {
                let mut client = ServeClient::connect(endpoint).unwrap();
                let mut rng = Pcg::new(100 + c);
                for _ in 0..per_client {
                    let start = rng.below(n_positions - 512);
                    assert_eq!(client.get_range(start, 512).unwrap().len(), 512);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "4-client burst (4 x 64 ranges)".into(),
        format!("{:.0} ranges/s", 4.0 * per_client as f64 / wall),
    ]);
    report.table(&["serve hot path", "median / rate"], &rows);
    let snap = server.stats_snapshot();
    report.line(format!(
        "server: {} ranges, p50 {} µs, p99 {} µs, {} shard loads ({} coalesced)",
        snap.requests,
        snap.p50_us().unwrap_or(0),
        snap.p99_us().unwrap_or(0),
        snap.shard_loads,
        snap.coalesced
    ));
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let mut report = Report::new("perf_hotpath", "Hot-path timings per layer");
    cache_layer_benches(&mut report);
    serve_layer_benches(&mut report);

    if !expt::artifacts_exist("artifacts/small") {
        println!("[engine sections skipped: artifacts/small missing]");
        report.finish();
        return;
    }
    let mut cfg = expt::config_for("artifacts/small", "perf");
    cfg.teacher_steps = 40; // perf pass does not need a good teacher
    let mut pipe = Pipeline::prepare(cfg).unwrap();
    let m = pipe.engine.manifest();
    let (b, s, v, k) = (m.batch, m.seq, m.vocab, m.k_slots);
    let cache = pipe.ensure_cache(&expt::spec("rs:rounds=50")).unwrap().unwrap().reader;

    let mut rows: Vec<Vec<String>> = Vec::new();
    let budget = Duration::from_millis(2500);

    // --- L3: batch assembly from cache (host) ---
    let mut loader = pipe.packed_loader(11, false, 0);
    let batch = loader.next_batch();
    let rs50 = Variant::Rs { rounds: 50, temp: 1.0 };
    let st = bench(2, budget, || {
        let blk = assemble_sparse_block(cache.as_ref(), &batch, v, k, rs50, None);
        std::hint::black_box(blk.val.len());
    });
    rows.push(vec!["L3 cache->block assembly".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L3: pure-rust RS sampling of one [B,S] block of teacher rows ---
    let probs = pipe
        .engine
        .call("fwd_teacher", &[pipe.teacher.params_tensor(),
                               HostTensor::i32(batch.tokens.clone(), &[b, s])])
        .unwrap()
        .remove(0);
    let pv = probs.as_f32().unwrap().to_vec();
    let st = bench(1, budget, || {
        let mut rng = Pcg::new(1);
        let mut acc = 0usize;
        for row in pv.chunks(v) {
            acc += rskd::sampling::random_sampling(row, 50, 1.0, &mut rng).k();
        }
        std::hint::black_box(acc);
    });
    rows.push(vec!["L3 rust RS sampler (B*S rows)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 sampler graph for the same block ---
    pipe.engine.warmup(&["sample_rs", "train_sparse_student", "train_sparse_jnp_student"]).unwrap();
    let n = m.n_rounds;
    let mut unif = vec![0.0f32; b * s * n];
    Pcg::new(2).fill_f32(&mut unif);
    let st = bench(2, budget, || {
        let out = pipe
            .engine
            .call("sample_rs", &[probs.clone(), HostTensor::f32(unif.clone(), &[b, s, n]),
                                 HostTensor::scalar_f32(1.0)])
            .unwrap();
        std::hint::black_box(out.len());
    });
    rows.push(vec!["L1 sample_rs graph (incl. transfer)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 vs L2: pallas vs jnp sparse train step ---
    let student = rskd::model::ModelState::init(&pipe.engine, "student", 1).unwrap();
    let blk = assemble_sparse_block(cache.as_ref(), &batch, v, k, rs50, None);
    let mk_args = || {
        let [p, mm, vv, stp] = student.opt_inputs();
        vec![
            p, mm, vv, stp,
            HostTensor::scalar_f32(1e-4),
            HostTensor::i32(batch.tokens.clone(), &[b, s]),
            HostTensor::i32(batch.labels.clone(), &[b, s]),
            HostTensor::i32(blk.idx.clone(), &[b, s, k]),
            HostTensor::f32(blk.val.clone(), &[b, s, k]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.smooth.clone(), &[b, s]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.lr_scale.clone(), &[b, s]),
        ]
    };
    for (label, graph) in [
        ("L1 train_sparse (pallas kernel)", "train_sparse_student"),
        ("L2 train_sparse_jnp (pure jnp)", "train_sparse_jnp_student"),
    ] {
        let args = mk_args();
        let st = bench(2, budget, || {
            let out = pipe.engine.call(graph, &args).unwrap();
            std::hint::black_box(out.len());
        });
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    // --- baseline steps for context ---
    for (label, graph, extra) in [
        ("train_ce step", "train_ce_student", 0usize),
        ("fwd_teacher", "fwd_teacher", 1),
    ] {
        let st = match extra {
            0 => {
                let [p, mm, vv, stp] = student.opt_inputs();
                let args = vec![p, mm, vv, stp, HostTensor::scalar_f32(1e-4),
                                HostTensor::i32(batch.tokens.clone(), &[b, s]),
                                HostTensor::i32(batch.labels.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
            _ => {
                let args = vec![pipe.teacher.params_tensor(),
                                HostTensor::i32(batch.tokens.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
        };
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    report.table(&["hot path", "median"], &rows);
    let es = pipe.engine.stats();
    report.line(format!(
        "engine totals: {} execs, exec {:.2}s, transfer {:.2}s ({:.0}% of exec+transfer)",
        es.executions,
        es.execute_time.as_secs_f64(),
        es.transfer_time.as_secs_f64(),
        100.0 * es.transfer_time.as_secs_f64()
            / (es.execute_time + es.transfer_time).as_secs_f64().max(1e-9)
    ));
    report.finish();
}
