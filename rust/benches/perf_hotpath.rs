//! Perf harness (EXPERIMENTS.md §Perf): per-layer hot-path timings.
//!  L1 vs L2 — Pallas sparse-KLD train step vs pure-jnp variant (identical
//!             numerics, different lowering).
//!  L3       — cache build throughput (1 vs N producers through the
//!             out-of-order writer), cold/warm lazy reads, cache block
//!             assembly, RS sampling (pure rust vs graph), host<->device
//!             transfer share from engine stats.
//!  serve    — loopback round-trip overhead of the sparse-logit server vs a
//!             direct reader call, and a 4-client concurrent burst with
//!             server-side p50/p99 (the `load-gen` subcommand is the
//!             heavier, configurable version of this section).
//!  cluster  — routed p50/p99 against a 3-server range-partitioned cluster
//!             under Zipf-skewed load, with and without hot-shard
//!             replication landed via a mid-run epoch bump (the `load-gen
//!             --cluster` subcommand is the multi-process version).
//!  observability — span-recording cost and traced-vs-untraced warm serve
//!             round-trips; under `RSKD_PERF_SMOKE=1` gates 0 allocs per
//!             recorded span and < 3% recording overhead per request.
//!  resilience — disabled fault-hook cost and deadline plumbing on the warm
//!             served path; under `RSKD_PERF_SMOKE=1` gates < 1% hook
//!             overhead per request and 0 extra allocs with a budget set.
//!  zero_copy — mapped vs heap shard I/O on warm and cold range reads, the
//!             bytes-copied ledger per warm range, and a loopback serve
//!             exchange over a mapped reader; under `RSKD_PERF_SMOKE=1`
//!             gates 0 payload bytes copied + 0 allocs on a warm raw mapped
//!             range and every served response scatter-written (`writev`).
//!
//! The cache-layer, serve, and assembly sections are host-only and run even
//! when `artifacts/` is missing, so the storage + serving + block-assembly
//! hot paths are benchmarkable on any machine.
//!
//! The assembly section measures the legacy allocating path against the
//! zero-allocation `assemble_sparse_block_into` path (tokens/sec plus
//! steady-state allocation counts from the counting-allocator harness in
//! `util::bench::alloc_count`) and emits `BENCH_hotpath.json` at the repo
//! root — the machine-readable perf trajectory later PRs append to (schema:
//! `docs/BENCH_SCHEMA.md`). With `RSKD_PERF_SMOKE=1` it runs tiny sizes and
//! *asserts* the new path allocates nothing at steady state and is not
//! slower than the old one — the CI perf gate.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rskd::cache::quant::ProbCodec;
use rskd::cache::{CacheReader, CacheWriter, RangeBlock, SparseTarget};
use rskd::coordinator::{
    assemble_sparse_block, assemble_sparse_block_into, AssembleScratch, Pipeline, SparseBlock,
};
use rskd::data::loader::Batch;
use rskd::expt;
use rskd::obs;
use rskd::report::Report;
use rskd::runtime::HostTensor;
use rskd::sampling::random_sampling;
use rskd::sampling::zipf::zipf;
use rskd::serve::{Endpoint, ServeClient, ServeConfig, Server};
use rskd::spec::{AdaptiveLr, Variant};
use rskd::util::bench::{alloc_count, bench};
use rskd::util::json::Json;
use rskd::util::rng::Pcg;

#[global_allocator]
static ALLOC: alloc_count::CountingAllocator = alloc_count::CountingAllocator;

/// Build an `n`-position cache with `producers` concurrent pushers (strided
/// interleave, so every shard sees every producer) and return positions/sec.
fn bench_cache_build(targets: &[SparseTarget], producers: usize, dir: &std::path::Path) -> f64 {
    let _ = std::fs::remove_dir_all(dir);
    let t0 = Instant::now();
    let w = CacheWriter::create(dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    std::thread::scope(|s| {
        for p in 0..producers {
            let w = &w;
            s.spawn(move || {
                for pos in (p..targets.len()).step_by(producers) {
                    assert!(w.push(pos as u64, targets[pos].clone()));
                }
            });
        }
    });
    let stats = w.finish().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(stats.positions as usize, targets.len());
    targets.len() as f64 / dt
}

fn cache_layer_benches(report: &mut Report) {
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(7);
    let n_positions = 16_384usize;
    let targets: Vec<SparseTarget> =
        (0..n_positions).map(|_| random_sampling(&p, 50, 1.0, &mut rng)).collect();
    let dir = std::env::temp_dir().join(format!("rskd-perf-cache-{}", std::process::id()));

    report.line("--- L3 cache build throughput (out-of-order writer, RS-50 targets) ---");
    let mut rows: Vec<Vec<String>> = Vec::new();
    // the last iteration leaves the 32-shard cache on disk for the read benches
    for producers in [1usize, 2, 4] {
        let pps = bench_cache_build(&targets, producers, &dir);
        rows.push(vec![
            format!("build, {producers} producer(s)"),
            format!("{:.0} positions/s", pps),
        ]);
    }
    report.table(&["cache build", "throughput"], &rows);

    let budget = Duration::from_millis(800);
    let mut rows: Vec<Vec<String>> = Vec::new();

    // cold open: metadata only (v1 decoded every shard here)
    let st = bench(1, budget, || {
        let r = CacheReader::open(&dir).unwrap();
        std::hint::black_box(r.shard_count());
    });
    rows.push(vec!["open (lazy, manifest only)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // cold read: every iteration reopens, so the first range decodes a shard
    let st = bench(1, budget, || {
        let r = CacheReader::open(&dir).unwrap();
        std::hint::black_box(r.get_range(4096, 512).len());
    });
    rows.push(vec!["cold get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // warm read: LRU hit path
    let r = CacheReader::open(&dir).unwrap();
    let _ = r.get_range(4096, 512);
    let st = bench(2, budget, || {
        std::hint::black_box(r.get_range(4096, 512).len());
    });
    rows.push(vec!["warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // full sequential sweep through a capacity-4 LRU (forced eviction churn)
    let st = bench(1, budget, || {
        let r = CacheReader::open_with_capacity(&dir, 4).unwrap();
        let mut acc = 0usize;
        for start in (0..n_positions as u64).step_by(512) {
            acc += r.get_range(start, 512).len();
        }
        std::hint::black_box(acc);
    });
    rows.push(vec![
        format!("sweep {n_positions} positions, LRU cap 4"),
        format!("{:.3} ms", st.per_iter_ms()),
    ]);
    report.table(&["cache read (lazy LRU reader)", "median"], &rows);
    report.line(format!(
        "cache on disk: {} shards, resident after warm range: {} shard(s)",
        r.shard_count(),
        r.resident_shards()
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Serving layer: wire round-trip vs direct reader, then a 4-client burst.
fn serve_layer_benches(report: &mut Report) {
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(11);
    let n_positions = 8192u64;
    let dir = std::env::temp_dir().join(format!("rskd-perf-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();

    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let ep = Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0)));
    let server = Server::start(Arc::clone(&reader), ep, ServeConfig::default()).unwrap();
    let endpoint = server.endpoint().clone();

    report.line("--- serve: loopback TCP server over the same cache ---");
    let budget = Duration::from_millis(800);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let direct = CacheReader::open(&dir).unwrap();
    let _ = direct.get_range(2048, 512); // warm the shard
    let st = bench(2, budget, || {
        std::hint::black_box(direct.get_range(2048, 512).len());
    });
    rows.push(vec!["direct warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);
    let mut client = ServeClient::connect(&endpoint).unwrap();
    let _ = client.get_range(2048, 512).unwrap();
    let st = bench(2, budget, || {
        std::hint::black_box(client.get_range(2048, 512).unwrap().len());
    });
    rows.push(vec!["served warm get_range(512)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // 4 concurrent clients sweeping overlapping ranges
    let t0 = Instant::now();
    let per_client = 64usize;
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let endpoint = &endpoint;
            s.spawn(move || {
                let mut client = ServeClient::connect(endpoint).unwrap();
                let mut rng = Pcg::new(100 + c);
                for _ in 0..per_client {
                    let start = rng.below(n_positions - 512);
                    assert_eq!(client.get_range(start, 512).unwrap().len(), 512);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    rows.push(vec![
        "4-client burst (4 x 64 ranges)".into(),
        format!("{:.0} ranges/s", 4.0 * per_client as f64 / wall),
    ]);
    report.table(&["serve hot path", "median / rate"], &rows);
    let snap = server.stats_snapshot();
    report.line(format!(
        "server: {} ranges, p50 {} µs, p99 {} µs, {} shard loads ({} coalesced)",
        snap.requests,
        snap.p50_us().unwrap_or(0),
        snap.p99_us().unwrap_or(0),
        snap.shard_loads,
        snap.coalesced
    ));
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Old-vs-new sparse-block assembly over a synthetic RS-50 cache (host-only:
/// no artifacts needed). Returns the `BENCH_hotpath.json` assembly object.
fn assembly_benches(report: &mut Report, smoke: bool) -> Json {
    // tiny sizes under RSKD_PERF_SMOKE=1 so CI can gate on this cheaply
    let (n_positions, b, s, k_slots) =
        if smoke { (2048usize, 4usize, 64usize, 32usize) } else { (16_384, 8, 256, 64) };
    let vocab = 512usize;
    let p = zipf(vocab, 1.0);
    let mut rng = Pcg::new(21);
    let dir = std::env::temp_dir().join(format!("rskd-perf-asm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions as u64 {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();
    // capacity >= shard count: steady-state reads must not evict/re-decode
    let reader = CacheReader::open_with_capacity(&dir, n_positions / 512 + 1).unwrap();

    // one fixed batch with scattered row offsets (the shuffled-loader shape)
    let rows = b * s;
    let batch = Batch {
        tokens: vec![1i32; rows],
        labels: (0..rows).map(|_| rng.below(vocab as u64) as i32).collect(),
        offsets: (0..b).map(|_| rng.below((n_positions - s) as u64) as usize).collect(),
        batch: b,
        seq: s,
    };
    let variant = Variant::Rs { rounds: 50, temp: 1.0 };
    let adaptive = Some(AdaptiveLr { ratio: 2.0, hard_frac: 0.3 });

    // correctness first: the zero-alloc path must be byte-identical
    let legacy = assemble_sparse_block(&reader, &batch, vocab, k_slots, variant, adaptive);
    let mut scratch = AssembleScratch::serial();
    let mut blk = SparseBlock::default();
    assemble_sparse_block_into(&reader, &batch, vocab, k_slots, variant, adaptive, &mut scratch,
                               &mut blk)
        .unwrap();
    assert_eq!(blk.idx, legacy.idx);
    assert_eq!(blk.val, legacy.val);
    assert_eq!(blk.smooth, legacy.smooth);
    assert_eq!(blk.lr_scale, legacy.lr_scale);

    let budget = Duration::from_millis(if smoke { 200 } else { 800 });
    report.line("--- assembly: cache -> SparseBlock, old (allocating) vs new (zero-alloc) ---");
    let counting = alloc_count::is_counting();

    let st_old = bench(2, budget, || {
        let blk = assemble_sparse_block(&reader, &batch, vocab, k_slots, variant, adaptive);
        std::hint::black_box(blk.val.len());
    });
    let (allocs_old, _) = alloc_count::measure(|| {
        let blk = assemble_sparse_block(&reader, &batch, vocab, k_slots, variant, adaptive);
        std::hint::black_box(blk.val.len());
    });

    let st_new = bench(2, budget, || {
        assemble_sparse_block_into(&reader, &batch, vocab, k_slots, variant, adaptive,
                                   &mut scratch, &mut blk)
            .unwrap();
        std::hint::black_box(blk.val.len());
    });
    let (allocs_new, _) = alloc_count::measure(|| {
        assemble_sparse_block_into(&reader, &batch, vocab, k_slots, variant, adaptive,
                                   &mut scratch, &mut blk)
            .unwrap();
        std::hint::black_box(blk.val.len());
    });

    let mut par_scratch = AssembleScratch::with_workers(0);
    let st_par = bench(2, budget, || {
        assemble_sparse_block_into(&reader, &batch, vocab, k_slots, variant, adaptive,
                                   &mut par_scratch, &mut blk)
            .unwrap();
        std::hint::black_box(blk.val.len());
    });

    let tps = |st: &rskd::util::bench::BenchStats| rows as f64 / st.median.as_secs_f64();
    let alloc_cell = |n: u64| {
        if counting { format!("{n}") } else { "n/a".into() }
    };
    report.table(
        &["assembly path", "median", "tokens/s", "allocs/step"],
        &[
            vec!["old: assemble_sparse_block".into(),
                 format!("{:.3} ms", st_old.per_iter_ms()),
                 format!("{:.0}", tps(&st_old)),
                 alloc_cell(allocs_old)],
            vec!["new: assemble_sparse_block_into (serial)".into(),
                 format!("{:.3} ms", st_new.per_iter_ms()),
                 format!("{:.0}", tps(&st_new)),
                 alloc_cell(allocs_new)],
            vec![format!("new: parallel ({} workers)", par_scratch.workers()),
                 format!("{:.3} ms", st_par.per_iter_ms()),
                 format!("{:.0}", tps(&st_par)),
                 "-".into()],
        ],
    );

    if smoke {
        assert!(counting, "smoke mode requires the counting allocator to be installed");
        assert_eq!(allocs_new, 0, "zero-alloc assembly path must not allocate at steady state");
        // 10% noise margin: the real gap is several x (no per-token vectors),
        // so this still catches any genuine regression without making the CI
        // gate flaky on a noisy shared runner
        assert!(
            st_new.median.as_secs_f64() <= st_old.median.as_secs_f64() * 1.10,
            "new assembly path regressed: new {:?} > old {:?} (+10% margin)",
            st_new.median,
            st_old.median
        );
        report.line("[smoke gate passed: 0 allocs/step, new <= old]");
    }
    let _ = std::fs::remove_dir_all(&dir);

    let path_obj = |st: &rskd::util::bench::BenchStats, allocs: Option<u64>| {
        let mut pairs = vec![
            ("ms_per_block", Json::num(st.per_iter_ms())),
            ("tokens_per_sec", Json::num(tps(st))),
        ];
        if let Some(a) = allocs {
            pairs.push(("allocs_per_step", Json::num(a as f64)));
        }
        Json::obj(pairs)
    };
    Json::obj(vec![
        ("config", Json::obj(vec![
            ("vocab", Json::num(vocab as f64)),
            ("batch", Json::num(b as f64)),
            ("seq", Json::num(s as f64)),
            ("k_slots", Json::num(k_slots as f64)),
            ("rounds", Json::num(50.0)),
            ("positions", Json::num(n_positions as f64)),
            ("smoke", Json::Bool(smoke)),
            ("alloc_counting", Json::Bool(counting)),
        ])),
        ("old", path_obj(&st_old, counting.then_some(allocs_old))),
        ("new_serial", path_obj(&st_new, counting.then_some(allocs_new))),
        ("new_parallel", Json::obj(vec![
            ("workers", Json::num(par_scratch.workers() as f64)),
            ("ms_per_block", Json::num(st_par.per_iter_ms())),
            ("tokens_per_sec", Json::num(tps(&st_par))),
        ])),
    ])
}

/// Byte-level shard codec section (runs in smoke mode too): bytes at rest
/// per codec over the same synthetic RS-50 zipf corpus, compression ratio vs
/// raw, warm range-decode timing, and the steady-state allocation count of a
/// compressed-directory read. Returns the `BENCH_hotpath.json` compression
/// object (schema: docs/BENCH_SCHEMA.md). Under `RSKD_PERF_SMOKE=1` this
/// *asserts* the zero-alloc decode contract on the compressed hot path and a
/// > 1.5x ratio for delta-packed-lz — the codec half of the CI perf gate.
fn compression_benches(report: &mut Report, smoke: bool) -> Json {
    use rskd::cache::{RangeBlock, ShardCodec};
    let n_positions = if smoke { 2048usize } else { 16_384 };
    let win = 512usize; // one full shard: the steady-state training window
    let vocab = 512usize;
    let p = zipf(vocab, 1.0);
    let mut rng = Pcg::new(33);
    let targets: Vec<SparseTarget> =
        (0..n_positions).map(|_| random_sampling(&p, 50, 1.0, &mut rng)).collect();
    let total_slots: u64 = targets.iter().map(|t| t.k() as u64).sum();

    let budget = Duration::from_millis(if smoke { 200 } else { 800 });
    let counting = alloc_count::is_counting();
    report.line("--- shard codecs: bytes at rest + warm decode (docs/CACHE_FORMAT.md §Codec) ---");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut codecs_json: Vec<(&'static str, Json)> = Vec::new();
    let mut raw_bytes = 0u64;
    let mut raw_block = RangeBlock::new();
    let mut lz_gate: Option<(f64, u64, bool)> = None; // (ratio, allocs, bit_identical)
    let swept =
        [ShardCodec::Raw, ShardCodec::Delta, ShardCodec::DeltaPacked, ShardCodec::DeltaPackedLz];
    for sc in swept {
        let dir = std::env::temp_dir()
            .join(format!("rskd-perf-codec-{}-{}", sc.name(), std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let w = CacheWriter::create_coded(&dir, ProbCodec::Count { rounds: 50 }, sc, 512, 256, None)
            .unwrap();
        for (pos, t) in targets.iter().enumerate() {
            assert!(w.push(pos as u64, t.clone()));
        }
        let stats = w.finish().unwrap();
        if sc == ShardCodec::Raw {
            raw_bytes = stats.bytes;
        }
        let ratio = raw_bytes as f64 / stats.bytes as f64;

        // warm decode: shard resident, block capacity grown — the zero-alloc
        // steady state the decode contract promises even for compressed dirs
        let r = CacheReader::open_with_capacity(&dir, n_positions / 512 + 1).unwrap();
        let mut block = RangeBlock::new();
        r.read_range_into(0, win, &mut block).unwrap();
        if sc == ShardCodec::Raw {
            raw_block = block.clone();
        }
        let identical = block == raw_block;
        assert!(identical, "{sc} decode differs from raw");
        let st = bench(2, budget, || {
            r.read_range_into(0, win, &mut block).unwrap();
            std::hint::black_box(block.len());
        });
        let (allocs, _) = alloc_count::measure(|| {
            r.read_range_into(0, win, &mut block).unwrap();
            std::hint::black_box(block.len());
        });
        if sc == ShardCodec::DeltaPackedLz {
            lz_gate = Some((ratio, allocs, identical));
        }

        rows.push(vec![
            sc.to_string(),
            format!("{} B", stats.bytes),
            format!("{:.2}", stats.bytes as f64 / n_positions as f64),
            format!("{ratio:.2}x"),
            format!("{:.3} ms", st.per_iter_ms()),
            if counting { format!("{allocs}") } else { "n/a".into() },
        ]);
        let mut pairs = vec![
            ("bytes", Json::num(stats.bytes as f64)),
            ("bytes_per_token", Json::num(stats.bytes as f64 / n_positions as f64)),
            ("bytes_per_slot", Json::num(stats.bytes as f64 / total_slots.max(1) as f64)),
            ("ratio_vs_raw", Json::num(ratio)),
            ("warm_ms_per_range", Json::num(st.per_iter_ms())),
        ];
        if counting {
            pairs.push(("allocs_per_range", Json::num(allocs as f64)));
        }
        codecs_json.push((sc.name(), Json::obj(pairs)));
        let _ = std::fs::remove_dir_all(&dir);
    }
    report.table(
        &["shard codec", "bytes", "B/token", "ratio vs raw", "warm range", "allocs/range"],
        &rows,
    );
    report.line("decoded RangeBlocks verified bit-identical across all codecs");

    if smoke {
        assert!(counting, "smoke mode requires the counting allocator to be installed");
        let (ratio, allocs, identical) = lz_gate.expect("delta-packed-lz must have run");
        assert!(identical, "compressed-origin decode must be bit-identical to raw");
        assert_eq!(allocs, 0, "warm compressed-dir decode must not allocate at steady state");
        assert!(ratio > 1.5, "delta-packed-lz ratio {ratio:.2} must exceed 1.5x");
        report.line(format!(
            "[smoke gate passed: 0 allocs/range on compressed decode, lz ratio {ratio:.2}x > 1.5x]"
        ));
    }

    Json::obj(vec![
        ("config", Json::obj(vec![
            ("vocab", Json::num(vocab as f64)),
            ("positions", Json::num(n_positions as f64)),
            ("range", Json::num(win as f64)),
            ("rounds", Json::num(50.0)),
            ("slots", Json::num(total_slots as f64)),
            ("smoke", Json::Bool(smoke)),
            ("alloc_counting", Json::Bool(counting)),
        ])),
        ("codecs", Json::obj(codecs_json)),
    ])
}

/// Cluster section (runs in smoke mode too): p50/p99 of routed range reads
/// under a Zipf-skewed start distribution against a 3-server in-process
/// cluster, before and after hot-shard replication lands via an epoch bump
/// mid-run. Every response is byte-verified against a direct reader — any
/// mismatch would be an accepted stale read. Returns the `BENCH_hotpath.json`
/// cluster object (schema: docs/BENCH_SCHEMA.md). Under `RSKD_PERF_SMOKE=1`
/// this *asserts* zero failed requests, zero stale reads, that the epoch bump
/// was actually observed (stale pins rejected, manifest refetched), and that
/// replication serves > 20% of segments from replicas — the cluster third of
/// the CI perf gate.
fn cluster_benches(report: &mut Report, smoke: bool) -> Json {
    use rskd::cluster::{partition, replicate_hot, ClusterControl, ClusterReader};

    let n_positions: u64 = if smoke { 4096 } else { 16_384 };
    let range = 256usize;
    let requests = if smoke { 96usize } else { 768 };
    let servers = 3usize;

    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(17);
    let base = std::env::temp_dir().join(format!("rskd-perf-cluster-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let dir = base.join("cache");
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();

    let eps: Vec<Endpoint> =
        (0..servers).map(|i| Endpoint::Unix(base.join(format!("m{i}.sock")))).collect();
    let manifest = partition(n_positions, &eps).unwrap();
    let members: Vec<(Server, Arc<ClusterControl>)> = eps
        .iter()
        .map(|ep| {
            let r = Arc::new(CacheReader::open(&dir).unwrap());
            let ctl = Arc::new(ClusterControl::new(manifest.clone(), ep.clone()));
            let srv =
                Server::start_cluster(r, ep.clone(), ServeConfig::default(), Arc::clone(&ctl))
                    .unwrap();
            (srv, ctl)
        })
        .collect();
    let direct = CacheReader::open(&dir).unwrap();

    // Zipf-skewed starts over 64 buckets: low positions are hot, so the
    // cluster's first shard carries most of the load and is the one
    // `replicate_hot` should pick
    let buckets = 64usize;
    let weights = zipf(buckets, 1.0);
    let mut cdf = Vec::with_capacity(buckets);
    let mut acc = 0.0f32;
    for w in &weights {
        acc += w;
        cdf.push(acc);
    }
    let span = n_positions - range as u64;
    let mut draw_rng = Pcg::new(29);
    let mut draw_start = move || {
        let u = draw_rng.below(1 << 20) as f32 / (1u64 << 20) as f32 * acc;
        let b = cdf.partition_point(|&c| c < u).min(buckets - 1);
        (b as u64 * span) / buckets as u64 + draw_rng.below(span / buckets as u64 + 1)
    };
    let starts_a: Vec<u64> = (0..requests).map(|_| draw_start()).collect();
    let starts_b: Vec<u64> = (0..requests).map(|_| draw_start()).collect();

    let reader = ClusterReader::from_manifest(manifest.clone()).unwrap();
    let mut failed = 0u64;
    let mut stale_reads = 0u64; // responses whose bytes differ from a direct read
    let mut run_pass = |starts: &[u64]| -> Vec<Duration> {
        let mut lat = Vec::with_capacity(starts.len());
        for &start in starts {
            let t0 = Instant::now();
            match reader.try_get_range(start, range) {
                Ok(got) => {
                    lat.push(t0.elapsed());
                    if got != direct.get_range(start, range) {
                        stale_reads += 1;
                    }
                }
                Err(_) => failed += 1,
            }
        }
        lat
    };

    report.line("--- cluster: 3-server routed reads under Zipf skew, +- hot-shard replication ---");
    let mut lat_a = run_pass(&starts_a);
    let c_a = reader.counters();

    // replicate the hottest shard (by the load phase A actually generated)
    // onto a second member and land it as an epoch bump while the reader is
    // still pinned to epoch 1
    let heat: Vec<(u64, u64, u64)> = manifest
        .shards()
        .iter()
        .map(|s| {
            let hits = starts_a.iter().filter(|&&st| st >= s.lo && st < s.hi).count() as u64;
            (s.lo, s.hi, hits)
        })
        .collect();
    let replicated = replicate_hot(&manifest, &heat, 1, 2).unwrap();
    for (_, ctl) in &members {
        ctl.update(replicated.clone()).unwrap();
    }
    let mut lat_b = run_pass(&starts_b);
    let c_b = reader.counters();
    let hit_rate = (c_b.replica_served - c_a.replica_served) as f64
        / (c_b.requests - c_a.requests).max(1) as f64;

    let pct = |lat: &mut Vec<Duration>, q: f64| -> f64 {
        lat.sort_unstable();
        lat[((lat.len() as f64 - 1.0) * q).round() as usize].as_secs_f64() * 1e3
    };
    let (a50, a99) = (pct(&mut lat_a, 0.50), pct(&mut lat_a, 0.99));
    let (b50, b99) = (pct(&mut lat_b, 0.50), pct(&mut lat_b, 0.99));
    report.table(
        &["cluster pass", "p50", "p99", "replica hit rate"],
        &[
            vec!["epoch 1, no replication".into(), format!("{a50:.3} ms"),
                 format!("{a99:.3} ms"), "-".into()],
            vec!["epoch 2, hot shard x2".into(), format!("{b50:.3} ms"),
                 format!("{b99:.3} ms"), format!("{hit_rate:.2}")],
        ],
    );
    report.line(format!(
        "cluster: {} requests, {} failed, {} stale reads accepted, {} stale pins rejected, \
         {} manifest refetches, final epoch {}",
        2 * requests,
        failed,
        stale_reads,
        c_b.stale_rejected,
        c_b.refetches,
        reader.manifest_epoch()
    ));

    if smoke {
        assert_eq!(failed, 0, "no routed request may fail");
        assert_eq!(stale_reads, 0, "no stale response may ever be accepted");
        assert!(c_b.stale_rejected >= 1, "the mid-run epoch bump must have been observed");
        assert!(c_b.refetches >= 1, "the reader must have refetched the manifest");
        assert_eq!(reader.manifest_epoch(), replicated.epoch());
        assert!(hit_rate > 0.2, "replica hit rate {hit_rate:.2} must exceed 0.2 under skew");
        report.line(format!(
            "[smoke gate passed: 0 failed, 0 stale, replica hit rate {hit_rate:.2} > 0.2]"
        ));
    }
    drop(members);
    let _ = std::fs::remove_dir_all(&base);

    Json::obj(vec![
        ("config", Json::obj(vec![
            ("servers", Json::num(servers as f64)),
            ("positions", Json::num(n_positions as f64)),
            ("range", Json::num(range as f64)),
            ("requests_per_phase", Json::num(requests as f64)),
            ("zipf_buckets", Json::num(buckets as f64)),
            ("hot_top_n", Json::num(1.0)),
            ("replicas", Json::num(2.0)),
            ("smoke", Json::Bool(smoke)),
        ])),
        ("no_replication", Json::obj(vec![
            ("p50_ms", Json::num(a50)),
            ("p99_ms", Json::num(a99)),
        ])),
        ("replication", Json::obj(vec![
            ("p50_ms", Json::num(b50)),
            ("p99_ms", Json::num(b99)),
            ("replica_hit_rate", Json::num(hit_rate)),
        ])),
        ("failed_requests", Json::num(failed as f64)),
        ("stale_reads", Json::num(stale_reads as f64)),
        ("stale_rejected", Json::num(c_b.stale_rejected as f64)),
        ("manifest_refetches", Json::num(c_b.refetches as f64)),
        ("epoch", Json::num(reader.manifest_epoch() as f64)),
    ])
}

/// Observability section (runs in smoke mode too): the cost of recording one
/// finished span into the bounded ring, the steady-state allocation count of
/// that recording path, and the end-to-end cost of tracing a warm served
/// range read (Root + Segment + Server spans per request) against the same
/// read untraced. Returns the `BENCH_hotpath.json` observability object.
/// With `RSKD_PERF_SMOKE=1` it *asserts* span recording allocates nothing at
/// steady state and that the computed per-request recording overhead stays
/// under 3% of the warm serve round-trip — the observability CI perf gate.
fn observability_benches(report: &mut Report, smoke: bool) -> Json {
    let budget = Duration::from_millis(if smoke { 200 } else { 800 });
    let counting = alloc_count::is_counting();
    report.line("--- observability: span recording + traced serve round-trips ---");

    // (1) raw span recording on a private ring (the global one keeps serving
    // the traced section below). Warm past capacity first: the ring buffer
    // is reserved up front, so steady state is pure overwrite.
    let ring = obs::SpanRing::new();
    for i in 0..obs::SPAN_RING_CAP as u64 {
        obs::SpanScope::begin(&ring, obs::SpanKind::Root, obs::mint_trace(), 0, 0, i, 1)
            .finish();
    }
    let batch = 64u64;
    let st_span = bench(2, budget, || {
        for i in 0..batch {
            let mut scope = obs::SpanScope::begin(
                &ring,
                obs::SpanKind::Segment,
                obs::mint_trace(),
                0,
                3,
                i,
                64,
            );
            scope.span_phase(obs::Phase::Network, Duration::from_nanos(50));
            scope.finish();
        }
    });
    let ns_per_span = st_span.median.as_nanos() as f64 / batch as f64;
    let (span_allocs, _) = alloc_count::measure(|| {
        for i in 0..256u64 {
            obs::SpanScope::begin(&ring, obs::SpanKind::Segment, obs::mint_trace(), 0, 3, i, 64)
                .finish();
        }
    });

    // (2) traced vs untraced warm serve round-trips over a loopback socket.
    // A traced request records three spans (client Root + Segment, server
    // Server), all landing in this process's global ring, and carries 8
    // extra bytes each way on the wire.
    let n_positions = if smoke { 2048usize } else { 8192 };
    let range = 256usize;
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(33);
    let dir = std::env::temp_dir().join(format!("rskd-perf-obs-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions as u64 {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server =
        Server::start(reader, Endpoint::Unix(dir.join("s.sock")), ServeConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();
    client.read_range_into(256, range, &mut block).unwrap(); // warm the shard

    let st_plain = bench(2, budget, || {
        client.read_range_into(256, range, &mut block).unwrap();
        std::hint::black_box(block.len());
    });
    let st_traced = bench(2, budget, || {
        let root = obs::SpanScope::begin(
            obs::spans(),
            obs::SpanKind::Root,
            obs::mint_trace(),
            0,
            u32::MAX,
            256,
            range as u32,
        );
        client.read_range_into(256, range, &mut block).unwrap();
        std::hint::black_box(block.len());
        root.finish();
    });
    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);

    // the gated number: what recording three spans costs relative to the
    // warm round-trip it annotates. The direct traced-vs-untraced delta is
    // reported too, but loopback noise makes it a poor hard gate at 3%.
    let spans_per_request = 3.0;
    let untraced_ns = st_plain.median.as_nanos() as f64;
    let overhead_pct = 100.0 * spans_per_request * ns_per_span / untraced_ns.max(1.0);
    let measured_pct =
        100.0 * (st_traced.median.as_secs_f64() / st_plain.median.as_secs_f64().max(1e-12) - 1.0);

    let alloc_cell = |n: u64| {
        if counting { format!("{n}") } else { "n/a".into() }
    };
    report.table(
        &["observability", "value"],
        &[
            vec!["span record (begin+phase+finish)".into(), format!("{ns_per_span:.0} ns/span")],
            vec!["allocs / 256 recorded spans".into(), alloc_cell(span_allocs)],
            vec!["untraced warm read_range_into(256)".into(),
                 format!("{:.3} ms", st_plain.per_iter_ms())],
            vec!["traced warm read_range_into(256)".into(),
                 format!("{:.3} ms", st_traced.per_iter_ms())],
            vec!["recording overhead (3 spans/request)".into(), format!("{overhead_pct:.3} %")],
            vec!["measured traced-vs-untraced delta".into(), format!("{measured_pct:+.2} %")],
        ],
    );

    if smoke {
        assert!(counting, "smoke mode requires the counting allocator to be installed");
        assert_eq!(span_allocs, 0, "span recording must not allocate at steady state");
        assert!(
            overhead_pct < 3.0,
            "span recording overhead {overhead_pct:.3}% >= 3% of a warm serve round-trip \
             ({ns_per_span:.0} ns/span x {spans_per_request} spans vs {untraced_ns:.0} ns)"
        );
        // 10% noise margin on the direct comparison: catches a gross
        // regression (an accidental lock or allocation on the traced path)
        // without making the gate flaky on loopback jitter
        assert!(
            st_traced.median.as_secs_f64() <= st_plain.median.as_secs_f64() * 1.10,
            "traced round-trip regressed: {:?} > {:?} (+10% margin)",
            st_traced.median,
            st_plain.median
        );
        report.line("[smoke gate passed: 0 allocs/span, recording overhead < 3%]");
    }

    Json::obj(vec![
        ("config", Json::obj(vec![
            ("positions", Json::num(n_positions as f64)),
            ("range_len", Json::num(range as f64)),
            ("span_batch", Json::num(batch as f64)),
            ("spans_per_request", Json::num(spans_per_request)),
            ("smoke", Json::Bool(smoke)),
            ("alloc_counting", Json::Bool(counting)),
        ])),
        ("span_record", Json::obj(vec![
            ("ns_per_span", Json::num(ns_per_span)),
            ("allocs_per_span", Json::num(if counting { span_allocs as f64 / 256.0 } else { -1.0 })),
        ])),
        ("traced_serve", Json::obj(vec![
            ("untraced_ms", Json::num(st_plain.per_iter_ms())),
            ("traced_ms", Json::num(st_traced.per_iter_ms())),
            ("measured_overhead_pct", Json::num(measured_pct)),
        ])),
        ("overhead_pct", Json::num(overhead_pct)),
    ])
}

/// Resilience section (runs in smoke mode too): what the fault-injection
/// hooks and deadline plumbing cost when *nothing is armed* — the
/// zero-cost-when-disabled contract of docs/RESILIENCE.md. Measures one
/// disabled hook (a relaxed load + branch), then a warm served range read
/// with and without a deadline budget. Returns the `BENCH_hotpath.json`
/// resilience object. Under `RSKD_PERF_SMOKE=1` it *asserts* the per-request
/// hook overhead stays under 1% of the warm round-trip and that carrying a
/// deadline budget adds zero allocations per range — the resilience CI gate.
fn resilience_benches(report: &mut Report, smoke: bool) -> Json {
    use rskd::fault::{self, FaultSite};
    let budget = Duration::from_millis(if smoke { 200 } else { 800 });
    let counting = alloc_count::is_counting();
    report.line("--- resilience: disabled fault hooks + deadline plumbing on the warm path ---");
    assert!(!fault::enabled(), "perf must run with no fault plan installed");

    // (1) one disabled hook: a relaxed load and a branch
    let batch = 1024u64;
    let st_check = bench(2, budget, || {
        for _ in 0..batch {
            std::hint::black_box(fault::fires(FaultSite::ServeJobDelay));
        }
    });
    let ns_per_check = st_check.median.as_nanos() as f64 / batch as f64;

    // (2) warm served range read, with and without a deadline budget (the
    // budget is generous — what is measured is the stamping, not expiry)
    let n_positions = if smoke { 2048usize } else { 8192 };
    let range = 256usize;
    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(41);
    let dir = std::env::temp_dir().join(format!("rskd-perf-res-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions as u64 {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server =
        Server::start(reader, Endpoint::Unix(dir.join("s.sock")), ServeConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();
    client.read_range_into(256, range, &mut block).unwrap(); // warm the shard

    let reads = 32u64;
    let st_plain = bench(2, budget, || {
        client.read_range_into(256, range, &mut block).unwrap();
        std::hint::black_box(block.len());
    });
    let (allocs_plain, _) = alloc_count::measure(|| {
        for _ in 0..reads {
            client.read_range_into(256, range, &mut block).unwrap();
        }
        std::hint::black_box(block.len());
    });
    client.deadline = Some(Duration::from_secs(5));
    let st_budget = bench(2, budget, || {
        client.read_range_into(256, range, &mut block).unwrap();
        std::hint::black_box(block.len());
    });
    let (allocs_budget, _) = alloc_count::measure(|| {
        for _ in 0..reads {
            client.read_range_into(256, range, &mut block).unwrap();
        }
        std::hint::black_box(block.len());
    });
    client.deadline = None;
    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);

    // the gated number: a warm served read crosses four disabled sites
    // (client drop; server drop, stall, job delay) plus the deadline-None
    // branch — what that costs relative to the round-trip it rides on. The
    // direct with-vs-without-budget delta is reported too, but loopback
    // noise makes it a poor hard gate at 1%.
    let checks_per_request = 5.0;
    let plain_ns = st_plain.median.as_nanos() as f64;
    let overhead_pct = 100.0 * checks_per_request * ns_per_check / plain_ns.max(1.0);
    let measured_pct =
        100.0 * (st_budget.median.as_secs_f64() / st_plain.median.as_secs_f64().max(1e-12) - 1.0);
    let alloc_cell = |n: u64| {
        if counting { format!("{n}") } else { "n/a".into() }
    };
    report.table(
        &["resilience", "value"],
        &[
            vec!["disabled fault hook".into(), format!("{ns_per_check:.2} ns/check")],
            vec!["warm served read, no deadline".into(),
                 format!("{:.3} ms", st_plain.per_iter_ms())],
            vec!["warm served read, 5s budget".into(),
                 format!("{:.3} ms", st_budget.per_iter_ms())],
            vec![format!("allocs / {reads} reads (no deadline)"), alloc_cell(allocs_plain)],
            vec![format!("allocs / {reads} reads (5s budget)"), alloc_cell(allocs_budget)],
            vec!["hook overhead (5 checks/request)".into(), format!("{overhead_pct:.4} %")],
            vec!["measured budget-vs-none delta".into(), format!("{measured_pct:+.2} %")],
        ],
    );

    if smoke {
        assert!(counting, "smoke mode requires the counting allocator to be installed");
        assert!(
            overhead_pct < 1.0,
            "disabled fault hooks cost {overhead_pct:.4}% >= 1% of a warm serve round-trip \
             ({ns_per_check:.2} ns/check x {checks_per_request} checks vs {plain_ns:.0} ns)"
        );
        assert_eq!(
            allocs_budget, allocs_plain,
            "carrying a deadline budget must not allocate on the warm read path"
        );
        // 10% noise margin on the direct comparison: catches a gross
        // regression (a syscall or lock on the budget path) without making
        // the gate flaky on loopback jitter
        assert!(
            st_budget.median.as_secs_f64() <= st_plain.median.as_secs_f64() * 1.10,
            "budgeted round-trip regressed: {:?} > {:?} (+10% margin)",
            st_budget.median,
            st_plain.median
        );
        report.line("[smoke gate passed: hook overhead < 1%, 0 extra allocs/range with a budget]");
    }

    Json::obj(vec![
        ("config", Json::obj(vec![
            ("positions", Json::num(n_positions as f64)),
            ("range_len", Json::num(range as f64)),
            ("checks_per_request", Json::num(checks_per_request)),
            ("smoke", Json::Bool(smoke)),
            ("alloc_counting", Json::Bool(counting)),
        ])),
        ("hook", Json::obj(vec![("ns_per_check", Json::num(ns_per_check))])),
        ("deadline_plumbing", Json::obj(vec![
            ("plain_ms", Json::num(st_plain.per_iter_ms())),
            ("budget_ms", Json::num(st_budget.per_iter_ms())),
            ("measured_delta_pct", Json::num(measured_pct)),
            ("allocs_plain", Json::num(if counting { allocs_plain as f64 } else { -1.0 })),
            ("allocs_budget", Json::num(if counting { allocs_budget as f64 } else { -1.0 })),
        ])),
        ("overhead_pct", Json::num(overhead_pct)),
    ])
}

/// Zero-copy I/O section (runs in smoke mode too): warm range reads under
/// mmap-backed vs heap shard I/O with the bytes-copied ledger on each, cold
/// open + first-range latency per mode, and a loopback serve exchange over a
/// mapped reader whose responses must be scatter-written
/// (`responses_vectored`) and byte-identical to a direct read. Returns the
/// `BENCH_hotpath.json` zero_copy object (schema: docs/BENCH_SCHEMA.md).
/// Under `RSKD_PERF_SMOKE=1` it *asserts* that a warm raw mapped range moves
/// 0 payload bytes through intermediate buffers and allocates nothing, and
/// that every served request on a little-endian host went out through the
/// vectored send path — the zero-copy CI perf gate.
fn zero_copy_benches(report: &mut Report, smoke: bool) -> Json {
    use rskd::cache::{IoMode, ReadOptions};
    use rskd::util::bench::copy_count;

    let n_positions = if smoke { 2048usize } else { 16_384 };
    let win = 512usize; // one full shard per range
    let vocab = 512usize;
    let p = zipf(vocab, 1.0);
    let mut rng = Pcg::new(57);
    let dir = std::env::temp_dir().join(format!("rskd-perf-zc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 256).unwrap();
    for pos in 0..n_positions as u64 {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();

    let budget = Duration::from_millis(if smoke { 200 } else { 800 });
    let counting = alloc_count::is_counting();
    report.line(
        "--- zero-copy I/O: mapped vs heap shard reads + vectored serve \
         (docs/CACHE_FORMAT.md §Mapped reads) ---",
    );
    let open_io = |io: IoMode| {
        CacheReader::open_with(
            &dir,
            ReadOptions { capacity: n_positions / 512 + 1, io, ..ReadOptions::default() },
        )
        .unwrap()
    };

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut modes_json: Vec<(&'static str, Json)> = Vec::new();
    let mut baseline = RangeBlock::new();
    let mut raw_gate: Option<(u64, u64)> = None; // (bytes copied, allocs) on warm mapped
    for (name, io) in [("mapped", IoMode::Mapped), ("heap", IoMode::Heap)] {
        // cold: reopen and decode the first shard every iteration
        let st_cold = bench(1, budget, || {
            let r = open_io(io);
            let mut b = RangeBlock::new();
            r.read_range_into(0, win, &mut b).unwrap();
            std::hint::black_box(b.len());
        });

        // warm: shard resident, block capacity grown — the steady state
        let r = open_io(io);
        let mut block = RangeBlock::new();
        r.read_range_into(0, win, &mut block).unwrap();
        if io == IoMode::Mapped {
            baseline = block.clone();
        }
        assert!(block == baseline, "heap decode differs from mapped");
        let st_warm = bench(2, budget, || {
            r.read_range_into(0, win, &mut block).unwrap();
            std::hint::black_box(block.len());
        });
        let (copied, _) = copy_count::measure(|| {
            r.read_range_into(0, win, &mut block).unwrap();
            std::hint::black_box(block.len());
        });
        let (allocs, _) = alloc_count::measure(|| {
            r.read_range_into(0, win, &mut block).unwrap();
            std::hint::black_box(block.len());
        });
        let effective = r.io_mode();
        if io == IoMode::Mapped && effective == IoMode::Mapped {
            raw_gate = Some((copied, allocs));
        }
        let tps = win as f64 / st_warm.median.as_secs_f64();
        rows.push(vec![
            format!("{name} (runs as {effective:?})"),
            format!("{:.3} ms", st_cold.per_iter_ms()),
            format!("{:.3} ms", st_warm.per_iter_ms()),
            format!("{:.0}", tps),
            format!("{copied} B"),
            if counting { format!("{allocs}") } else { "n/a".into() },
        ]);
        modes_json.push((
            name,
            Json::obj(vec![
                ("effective_mapped", Json::Bool(effective == IoMode::Mapped)),
                ("cold_ms_open_plus_range", Json::num(st_cold.per_iter_ms())),
                ("warm_ms_per_range", Json::num(st_warm.per_iter_ms())),
                ("warm_tokens_per_sec", Json::num(tps)),
                ("warm_bytes_copied_per_range", Json::num(copied as f64)),
                ("warm_allocs_per_range", Json::num(if counting { allocs as f64 } else { -1.0 })),
            ]),
        ));
    }
    report.table(
        &["shard I/O mode", "cold open+range", "warm range", "tokens/s", "copied/range",
          "allocs/range"],
        &rows,
    );

    // loopback serve over a mapped reader: every response must decode to the
    // same bytes a direct read produces, and on little-endian hosts must have
    // been scatter-written from the worker's block
    let reader = Arc::new(open_io(IoMode::Mapped));
    let server =
        Server::start(reader, Endpoint::Unix(dir.join("zc.sock")), ServeConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut served = RangeBlock::new();
    client.read_range_into(0, win, &mut served).unwrap(); // warm
    assert!(served == baseline, "served range differs from direct mapped read");
    let st_serve = bench(2, budget, || {
        client.read_range_into(0, win, &mut served).unwrap();
        std::hint::black_box(served.len());
    });
    assert!(served == baseline, "served range differs from direct mapped read");
    let snap = server.stats_snapshot();
    let vectored_all = snap.responses_vectored == snap.requests && snap.requests > 0;
    rows = vec![
        vec!["served warm range, mapped reader".into(), format!("{:.3} ms", st_serve.per_iter_ms())],
        vec!["responses vectored".into(),
             format!("{} / {}", snap.responses_vectored, snap.requests)],
    ];
    report.table(&["vectored serve", "value"], &rows);
    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);

    if smoke {
        assert!(counting, "smoke mode requires the counting allocator to be installed");
        if cfg!(unix) {
            let (copied, allocs) =
                raw_gate.expect("mapped mode must not degrade to heap on unix");
            assert_eq!(copied, 0, "warm raw mapped range must copy 0 payload bytes");
            assert_eq!(allocs, 0, "warm raw mapped range must not allocate at steady state");
        }
        if cfg!(target_endian = "little") {
            assert!(
                vectored_all,
                "every served response must go out vectored on LE ({} of {})",
                snap.responses_vectored, snap.requests
            );
        }
        report.line("[smoke gate passed: 0 bytes copied + 0 allocs warm mapped, serve vectored]");
    }

    Json::obj(vec![
        ("config", Json::obj(vec![
            ("vocab", Json::num(vocab as f64)),
            ("positions", Json::num(n_positions as f64)),
            ("range", Json::num(win as f64)),
            ("rounds", Json::num(50.0)),
            ("smoke", Json::Bool(smoke)),
            ("alloc_counting", Json::Bool(counting)),
        ])),
        ("modes", Json::obj(modes_json)),
        ("serve", Json::obj(vec![
            ("warm_ms_per_range", Json::num(st_serve.per_iter_ms())),
            ("requests", Json::num(snap.requests as f64)),
            ("responses_vectored", Json::num(snap.responses_vectored as f64)),
        ])),
    ])
}

fn main() {
    let smoke = std::env::var("RSKD_PERF_SMOKE").map(|v| v == "1").unwrap_or(false);
    let mut report = Report::new("perf_hotpath", "Hot-path timings per layer");
    let assembly = assembly_benches(&mut report, smoke);
    let compression = compression_benches(&mut report, smoke);
    let cluster = cluster_benches(&mut report, smoke);
    let observability = observability_benches(&mut report, smoke);
    let resilience = resilience_benches(&mut report, smoke);
    let zero_copy = zero_copy_benches(&mut report, smoke);
    let bench_json = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("bench", Json::str("perf_hotpath")),
        ("assembly", assembly),
        ("compression", compression),
        ("cluster", cluster),
        ("observability", observability),
        ("resilience", resilience),
        ("zero_copy", zero_copy),
    ]);
    // the repo-root perf trajectory point (schema: docs/BENCH_SCHEMA.md)
    match std::fs::write("BENCH_hotpath.json", bench_json.to_string()) {
        Ok(()) => println!("[BENCH_hotpath.json written]"),
        Err(e) => eprintln!("warning: could not write BENCH_hotpath.json: {e}"),
    }
    if smoke {
        println!("[smoke mode: skipping cache/serve/engine sections]");
        report.finish();
        return;
    }
    cache_layer_benches(&mut report);
    serve_layer_benches(&mut report);

    if !expt::artifacts_exist("artifacts/small") {
        println!("[engine sections skipped: artifacts/small missing]");
        report.finish();
        return;
    }
    let mut cfg = expt::config_for("artifacts/small", "perf");
    cfg.teacher_steps = 40; // perf pass does not need a good teacher
    let mut pipe = Pipeline::prepare(cfg).unwrap();
    let m = pipe.engine.manifest();
    let (b, s, v, k) = (m.batch, m.seq, m.vocab, m.k_slots);
    let cache = pipe.ensure_cache(&expt::spec("rs:rounds=50")).unwrap().unwrap().reader;

    let mut rows: Vec<Vec<String>> = Vec::new();
    let budget = Duration::from_millis(2500);

    // --- L3: batch assembly from cache (host) ---
    let mut loader = pipe.packed_loader(11, false, 0);
    let batch = loader.next_batch();
    let rs50 = Variant::Rs { rounds: 50, temp: 1.0 };
    let st = bench(2, budget, || {
        let blk = assemble_sparse_block(cache.as_ref(), &batch, v, k, rs50, None);
        std::hint::black_box(blk.val.len());
    });
    rows.push(vec!["L3 cache->block assembly".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L3: pure-rust RS sampling of one [B,S] block of teacher rows ---
    let probs = pipe
        .engine
        .call("fwd_teacher", &[pipe.teacher.params_tensor(),
                               HostTensor::i32(batch.tokens.clone(), &[b, s])])
        .unwrap()
        .remove(0);
    let pv = probs.as_f32().unwrap().to_vec();
    let st = bench(1, budget, || {
        let mut rng = Pcg::new(1);
        let mut acc = 0usize;
        for row in pv.chunks(v) {
            acc += rskd::sampling::random_sampling(row, 50, 1.0, &mut rng).k();
        }
        std::hint::black_box(acc);
    });
    rows.push(vec!["L3 rust RS sampler (B*S rows)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 sampler graph for the same block ---
    pipe.engine.warmup(&["sample_rs", "train_sparse_student", "train_sparse_jnp_student"]).unwrap();
    let n = m.n_rounds;
    let mut unif = vec![0.0f32; b * s * n];
    Pcg::new(2).fill_f32(&mut unif);
    let st = bench(2, budget, || {
        let out = pipe
            .engine
            .call("sample_rs", &[probs.clone(), HostTensor::f32(unif.clone(), &[b, s, n]),
                                 HostTensor::scalar_f32(1.0)])
            .unwrap();
        std::hint::black_box(out.len());
    });
    rows.push(vec!["L1 sample_rs graph (incl. transfer)".into(), format!("{:.3} ms", st.per_iter_ms())]);

    // --- L1 vs L2: pallas vs jnp sparse train step ---
    let student = rskd::model::ModelState::init(&pipe.engine, "student", 1).unwrap();
    let blk = assemble_sparse_block(cache.as_ref(), &batch, v, k, rs50, None);
    let mk_args = || {
        let [p, mm, vv, stp] = student.opt_inputs();
        vec![
            p, mm, vv, stp,
            HostTensor::scalar_f32(1e-4),
            HostTensor::i32(batch.tokens.clone(), &[b, s]),
            HostTensor::i32(batch.labels.clone(), &[b, s]),
            HostTensor::i32(blk.idx.clone(), &[b, s, k]),
            HostTensor::f32(blk.val.clone(), &[b, s, k]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.smooth.clone(), &[b, s]),
            HostTensor::scalar_f32(0.0),
            HostTensor::f32(blk.lr_scale.clone(), &[b, s]),
        ]
    };
    for (label, graph) in [
        ("L1 train_sparse (pallas kernel)", "train_sparse_student"),
        ("L2 train_sparse_jnp (pure jnp)", "train_sparse_jnp_student"),
    ] {
        let args = mk_args();
        let st = bench(2, budget, || {
            let out = pipe.engine.call(graph, &args).unwrap();
            std::hint::black_box(out.len());
        });
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    // --- baseline steps for context ---
    for (label, graph, extra) in [
        ("train_ce step", "train_ce_student", 0usize),
        ("fwd_teacher", "fwd_teacher", 1),
    ] {
        let st = match extra {
            0 => {
                let [p, mm, vv, stp] = student.opt_inputs();
                let args = vec![p, mm, vv, stp, HostTensor::scalar_f32(1e-4),
                                HostTensor::i32(batch.tokens.clone(), &[b, s]),
                                HostTensor::i32(batch.labels.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
            _ => {
                let args = vec![pipe.teacher.params_tensor(),
                                HostTensor::i32(batch.tokens.clone(), &[b, s])];
                bench(2, budget, || {
                    std::hint::black_box(pipe.engine.call(graph, &args).unwrap().len());
                })
            }
        };
        rows.push(vec![label.into(), format!("{:.3} ms", st.per_iter_ms())]);
    }

    report.table(&["hot path", "median"], &rows);
    let es = pipe.engine.stats();
    report.line(format!(
        "engine totals: {} execs, exec {:.2}s, transfer {:.2}s ({:.0}% of exec+transfer)",
        es.executions,
        es.execute_time.as_secs_f64(),
        es.transfer_time.as_secs_f64(),
        100.0 * es.transfer_time.as_secs_f64()
            / (es.execute_time + es.transfer_time).as_secs_f64().max(1e-9)
    ));
    report.finish();
}
