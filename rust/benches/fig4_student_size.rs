//! Figure 4: downstream improvement of RS-KD over CE vs student size.
//! Requires `artifacts/sizes` (4 student dims + shared teacher); run
//! `cd python && python -m compile.aot --config sizes --out ../artifacts`.
//! Expectation: the 0-shot gain over CE grows (or at least does not fall)
//! with student size — the paper's contrast with Peng et al.'s Top-K drop.

use rskd::coordinator::schedule::LrSchedule;
use rskd::coordinator::{train_student, Pipeline};
use rskd::expt;
use rskd::model::ModelState;
use rskd::report::Report;

fn main() {
    if !expt::artifacts_exist("artifacts/sizes") {
        println!("[skipped: artifacts/sizes missing — `make artifacts-sizes` or aot --config sizes]");
        return;
    }
    let cfg = expt::config_for("artifacts/sizes", "fig4");
    let steps = cfg.student_steps;
    let lr = cfg.student_lr;
    let mut pipe = Pipeline::prepare(cfg).unwrap();
    let rs12 = expt::spec("rs:rounds=12");
    let cache = pipe.ensure_cache(&rs12).unwrap().unwrap();

    let mut report = Report::new("fig4_student_size", "Improvement vs student size (paper Figure 4)");
    let mut rows = Vec::new();
    let roles: Vec<String> = pipe
        .engine
        .manifest()
        .roles
        .keys()
        .filter(|r| r.starts_with('s') && *r != "teacher")
        .cloned()
        .collect();
    for role in roles {
        let params = pipe.engine.manifest().role(&role).unwrap().param_count;
        let mut scores = Vec::new();
        for spec in [expt::spec("ce"), rs12] {
            let mut student = ModelState::init(&pipe.engine, &role, 3).unwrap();
            let mut loader = pipe.train_loader(11);
            train_student(
                &pipe.engine,
                &mut student,
                &mut loader,
                steps,
                LrSchedule::paper_default(lr, steps),
                &spec,
                Some(cache.reader.as_ref()),
                Some(&pipe.teacher),
            )
            .unwrap();
            scores.push(expt::zero_shot(&pipe, &student).unwrap());
        }
        rows.push(vec![
            format!("{role} ({params} params)"),
            format!("{:.1}", scores[0]),
            format!("{:.1}", scores[1]),
            format!("{:+.1}", scores[1] - scores[0]),
        ]);
    }
    report.table(&["student", "CE 0-shot", "RS-KD 0-shot", "improvement"], &rows);
    report.finish();
}
