//! Table 3: gradient similarity — angular difference and norm ratio of each
//! sparse-KD gradient vs the FullKD gradient on the same batch, measured at
//! a FullKD-trained student checkpoint (paper §4.2). Expectation: RS-KD at
//! ~12 tokens shows a few degrees and norm ratio ~1; Top-K is tens of
//! degrees with inflated norms.

use rskd::coordinator::assemble_sparse_block;
use rskd::expt;
use rskd::metrics::gradsim::grad_similarity;
use rskd::report::Report;
use rskd::runtime::HostTensor;
use rskd::spec::{DistillSpec, Objective};

/// The sparse variant a spec string names (these presets are all sparse).
fn variant_of(spec: &DistillSpec) -> rskd::spec::Variant {
    match spec.objective {
        Objective::Sparse { variant, .. } => variant,
        _ => panic!("table3 cases are sparse specs"),
    }
}

fn main() {
    let Some(mut pipe) = expt::prepare_small("table3") else { return };
    let m = pipe.engine.manifest();
    let (b, s, v, k_slots) = (m.batch, m.seq, m.vocab, m.k_slots);

    // FullKD-trained checkpoint (paper: "a 300M model trained with FullKD")
    let (student, _, _) = pipe.run_spec(&expt::spec("fullkd"), 3).unwrap();

    // the registry hands back one shared Top-K cache and one RS-12 cache
    let tk = pipe.ensure_cache(&expt::spec("topk:k=12")).unwrap().unwrap();
    let rs = pipe.ensure_cache(&expt::spec("rs:rounds=12")).unwrap().unwrap();

    // one global batch, stream-ordered
    let mut loader = pipe.packed_loader(11, false, 0);
    let batch = loader.next_batch();
    let toks = HostTensor::i32(batch.tokens.clone(), &[b, s]);
    let labels = HostTensor::i32(batch.labels.clone(), &[b, s]);

    // reference: FullKD gradient (dense teacher probs)
    let tprobs = pipe
        .engine
        .call("fwd_teacher", &[pipe.teacher.params_tensor(), toks.clone()])
        .unwrap()
        .remove(0);
    let reference = pipe
        .engine
        .call(
            "grad_dense_student",
            &[student.params_tensor(), toks.clone(), labels.clone(), tprobs,
              HostTensor::scalar_f32(0.0)],
        )
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();

    let mut report = Report::new("table3_gradients", "Sparse-KD gradients vs FullKD (paper Table 3)");
    let mut rows = Vec::new();
    let cases: Vec<(String, &rskd::coordinator::CacheHandle, DistillSpec)> = vec![
        ("Top-K 12".into(), &tk, expt::spec("topk:k=12")),
        ("Top-K 50".into(), &tk, expt::spec("topk:k=50")),
        ("Top-K 64".into(), &tk, expt::spec("topk:k=64")),
        (
            format!("RS ({:.1} uniq)", rs.stats.avg_unique_tokens),
            &rs,
            expt::spec("rs:rounds=12"),
        ),
    ];
    for (name, cache, spec) in cases {
        let variant = variant_of(&spec);
        let blk = assemble_sparse_block(cache.reader.as_ref(), &batch, v, k_slots, variant, None);
        let g = pipe
            .engine
            .call(
                "grad_sparse_student",
                &[
                    student.params_tensor(),
                    toks.clone(),
                    labels.clone(),
                    HostTensor::i32(blk.idx, &[b, s, k_slots]),
                    HostTensor::f32(blk.val, &[b, s, k_slots]),
                    HostTensor::scalar_f32(0.0),
                    HostTensor::f32(blk.smooth, &[b, s]),
                    HostTensor::scalar_f32(blk.ghost_on),
                    HostTensor::f32(blk.lr_scale, &[b, s]),
                ],
            )
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        let sim = grad_similarity(&g, &reference);
        rows.push(vec![
            name,
            format!("{:.0}°", sim.angle_deg),
            format!("{:.2}", sim.norm_ratio),
        ]);
    }
    report.table(&["Method", "Δ Angle", "Norm Ratio"], &rows);
    report.finish();
}
