//! Table 3: gradient similarity — angular difference and norm ratio of each
//! sparse-KD gradient vs the FullKD gradient on the same batch, measured at
//! a FullKD-trained student checkpoint (paper §4.2). Expectation: RS-KD at
//! ~12 tokens shows a few degrees and norm ratio ~1; Top-K is tens of
//! degrees with inflated norms.

use rskd::coordinator::trainer::{assemble_sparse_block, SparseVariant};
use rskd::coordinator::{CacheKind, StudentMethod};
use rskd::expt;
use rskd::metrics::gradsim::grad_similarity;
use rskd::report::Report;
use rskd::runtime::HostTensor;

fn main() {
    let Some(pipe) = expt::prepare_small("table3") else { return };
    let m = pipe.engine.manifest();
    let (b, s, v, k_slots) = (m.batch, m.seq, m.vocab, m.k_slots);

    // FullKD-trained checkpoint (paper: "a 300M model trained with FullKD")
    let (student, _, _) = pipe
        .run_student(&StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None, 3)
        .unwrap();

    let (tk_cache, _) = pipe.build_cache(CacheKind::TopK, "t3-tk", 1).unwrap();
    let (rs_cache, rs_stats) = pipe
        .build_cache(CacheKind::Rs { rounds: 12, temp: 1.0 }, "t3-rs", 2)
        .unwrap();

    // one global batch, stream-ordered
    let mut loader = pipe.packed_loader(11, false, 0);
    let batch = loader.next_batch();
    let toks = HostTensor::i32(batch.tokens.clone(), &[b, s]);
    let labels = HostTensor::i32(batch.labels.clone(), &[b, s]);

    // reference: FullKD gradient (dense teacher probs)
    let tprobs = pipe
        .engine
        .call("fwd_teacher", &[pipe.teacher.params_tensor(), toks.clone()])
        .unwrap()
        .remove(0);
    let reference = pipe
        .engine
        .call(
            "grad_dense_student",
            &[student.params_tensor(), toks.clone(), labels.clone(), tprobs,
              HostTensor::scalar_f32(0.0)],
        )
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();

    let mut report = Report::new("table3_gradients", "Sparse-KD gradients vs FullKD (paper Table 3)");
    let mut rows = Vec::new();
    let cases: Vec<(String, &rskd::cache::CacheReader, SparseVariant)> = vec![
        ("Top-K 12".into(), &tk_cache, SparseVariant::TopK { k: 12, normalize: false }),
        ("Top-K 50".into(), &tk_cache, SparseVariant::TopK { k: 50, normalize: false }),
        ("Top-K 64".into(), &tk_cache, SparseVariant::TopK { k: 64, normalize: false }),
        (
            format!("RS ({:.1} uniq)", rs_stats.avg_unique_tokens),
            &rs_cache,
            SparseVariant::Rs,
        ),
    ];
    for (name, cache, variant) in cases {
        let blk = assemble_sparse_block(cache, &batch, v, k_slots, variant, None);
        let g = pipe
            .engine
            .call(
                "grad_sparse_student",
                &[
                    student.params_tensor(),
                    toks.clone(),
                    labels.clone(),
                    HostTensor::i32(blk.idx, &[b, s, k_slots]),
                    HostTensor::f32(blk.val, &[b, s, k_slots]),
                    HostTensor::scalar_f32(0.0),
                    HostTensor::f32(blk.smooth, &[b, s]),
                    HostTensor::scalar_f32(blk.ghost_on),
                    HostTensor::f32(blk.lr_scale, &[b, s]),
                ],
            )
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        let sim = grad_similarity(&g, &reference);
        rows.push(vec![
            name,
            format!("{:.0}°", sim.angle_deg),
            format!("{:.2}", sim.norm_ratio),
        ]);
    }
    report.table(&["Method", "Δ Angle", "Norm Ratio"], &rows);
    report.finish();
}
