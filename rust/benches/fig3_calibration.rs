//! Figure 3: LLM pre-training calibration.
//!  3a — reliability diagrams (confidence bin vs accuracy) for
//!       CE / Top-K 12 / RS-KD 12 / FullKD students.
//!  3b — ECE vs token budget for Top-K and RS-KD.

use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(mut pipe) = expt::prepare_small("fig3") else { return };

    let mut report = Report::new("fig3_calibration", "LLM pre-training calibration (paper Figure 3)");
    report.line("--- Fig 3a: reliability diagrams (bin conf -> accuracy) ---");

    let mut curves = Vec::new();
    for (name, s) in [
        ("CE", "ce"),
        ("Top-K 12", "topk:k=12"),
        ("RS-KD 12", "rs:rounds=12"),
        ("FullKD", "fullkd"),
    ] {
        let (_, _, ev) = pipe.run_spec(&expt::spec(s), 3).unwrap();
        curves.push((name, ev));
    }
    let mut rows = Vec::new();
    for (name, ev) in &curves {
        for b in &ev.calibration.bins {
            if b.count < 20 {
                continue;
            }
            rows.push(vec![
                name.to_string(),
                format!("{:.2}-{:.2}", b.lo, b.hi),
                format!("{:.3}", b.mean_conf),
                format!("{:.3}", b.accuracy),
                format!("{}", b.count),
            ]);
        }
    }
    report.table(&["method", "bin", "mean conf", "accuracy", "n"], &rows);

    report.line("--- Fig 3b: ECE vs token budget ---");
    let mut rows = Vec::new();
    for k in [5usize, 12, 25, 50] {
        let (_, _, ev_tk) = pipe.run_spec(&expt::spec(&format!("topk:k={k}")), 3).unwrap();
        let rs = expt::spec(&format!("rs:rounds={k}"));
        let handle = pipe.ensure_cache(&rs).unwrap().unwrap();
        let (_, _, ev_rs) = pipe.run_spec(&rs, 3).unwrap();
        rows.push(vec![
            format!("{k}"),
            format!("{:.1}", ev_tk.ece_pct),
            format!("{:.1} ({:.1} uniq)", ev_rs.ece_pct, handle.stats.avg_unique_tokens),
        ]);
    }
    report.table(&["tokens", "Top-K ECE %", "RS-KD ECE %"], &rows);
    report.finish();
}
