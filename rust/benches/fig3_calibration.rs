//! Figure 3: LLM pre-training calibration.
//!  3a — reliability diagrams (confidence bin vs accuracy) for
//!       CE / Top-K 12 / RS-KD 12 / FullKD students.
//!  3b — ECE vs token budget for Top-K and RS-KD.

use rskd::coordinator::{CacheKind, StudentMethod};
use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(pipe) = expt::prepare_small("fig3") else { return };
    let (tk_cache, _) = pipe.build_cache(CacheKind::TopK, "f3-tk", 1).unwrap();
    let (rs_cache, _) = pipe.build_cache(CacheKind::Rs { rounds: 12, temp: 1.0 }, "f3-rs", 2).unwrap();

    let mut report = Report::new("fig3_calibration", "LLM pre-training calibration (paper Figure 3)");
    report.line("--- Fig 3a: reliability diagrams (bin conf -> accuracy) ---");

    let runs: Vec<(&str, StudentMethod, Option<&rskd::cache::CacheReader>)> = vec![
        ("CE", StudentMethod::Ce, None),
        ("Top-K 12", expt::topk(12), Some(&tk_cache)),
        ("RS-KD 12", expt::rs(), Some(&rs_cache)),
        ("FullKD", StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None),
    ];
    let mut curves = Vec::new();
    for (name, method, cache) in runs {
        let (_, _, ev) = pipe.run_student(&method, cache, 3).unwrap();
        curves.push((name, ev));
    }
    let mut rows = Vec::new();
    for (name, ev) in &curves {
        for b in &ev.calibration.bins {
            if b.count < 20 {
                continue;
            }
            rows.push(vec![
                name.to_string(),
                format!("{:.2}-{:.2}", b.lo, b.hi),
                format!("{:.3}", b.mean_conf),
                format!("{:.3}", b.accuracy),
                format!("{}", b.count),
            ]);
        }
    }
    report.table(&["method", "bin", "mean conf", "accuracy", "n"], &rows);

    report.line("--- Fig 3b: ECE vs token budget ---");
    let mut rows = Vec::new();
    for k in [5usize, 12, 25, 50] {
        let (_, _, ev_tk) = pipe.run_student(&expt::topk(k), Some(&tk_cache), 3).unwrap();
        let (rs_c, stats) = pipe
            .build_cache(CacheKind::Rs { rounds: k as u32, temp: 1.0 }, &format!("f3-rs{k}"), k as u64)
            .unwrap();
        let (_, _, ev_rs) = pipe.run_student(&expt::rs(), Some(&rs_c), 3).unwrap();
        rows.push(vec![
            format!("{k}"),
            format!("{:.1}", ev_tk.ece_pct),
            format!("{:.1} ({:.1} uniq)", ev_rs.ece_pct, stats.avg_unique_tokens),
        ]);
    }
    report.table(&["tokens", "Top-K ECE %", "RS-KD ECE %"], &rows);
    report.finish();
}
