//! Table 12: loss/divergence ablation — CE, L1, MSE, reverse KLD, F+R, and
//! forward KLD (dense targets, online teacher). Expectation: forward KLD
//! wins; L1 diverges; MSE much worse.

use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(mut pipe) = expt::prepare_small("table12") else { return };
    let mut report = Report::new("table12_losses", "Loss ablation (paper Table 12)");
    let mut rows = Vec::new();
    // paper row labels (the forward-KLD row is "KLD (F)" in Table 12, not
    // the "FullKD" display name the spec uses elsewhere)
    for (name, s) in [
        ("CE", "ce"),
        ("L1", "l1"),
        ("MSE", "mse"),
        ("KLD (R)", "rkl"),
        ("KLD (F+R)", "frkl"),
        ("KLD (F)", "fullkd"),
    ] {
        let (_, tr, ev) = pipe.run_spec(&expt::spec(s), 3).unwrap();
        let loss = if tr.diverged || !ev.lm_loss.is_finite() {
            "inf (diverged)".to_string()
        } else {
            format!("{:.3}", ev.lm_loss)
        };
        rows.push(vec![name.to_string(), loss]);
    }
    report.table(&["Loss fn", "LM Loss"], &rows);
    report.finish();
}
