//! Table 12: loss/divergence ablation — CE, L1, MSE, reverse KLD, F+R, and
//! forward KLD (dense targets, online teacher). Expectation: forward KLD
//! wins; L1 diverges; MSE much worse.

use rskd::coordinator::StudentMethod;
use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(pipe) = expt::prepare_small("table12") else { return };
    let mut report = Report::new("table12_losses", "Loss ablation (paper Table 12)");
    let mut rows = Vec::new();
    let runs: Vec<(&str, StudentMethod)> = vec![
        ("CE", StudentMethod::Ce),
        ("L1", StudentMethod::DenseOnline { kind: "l1", alpha: 0.0 }),
        ("MSE", StudentMethod::DenseOnline { kind: "mse", alpha: 0.0 }),
        ("KLD (R)", StudentMethod::DenseOnline { kind: "rkl", alpha: 0.0 }),
        ("KLD (F+R)", StudentMethod::DenseOnline { kind: "frkl", alpha: 0.0 }),
        ("KLD (F)", StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }),
    ];
    for (name, method) in runs {
        let (_, tr, ev) = pipe.run_student(&method, None, 3).unwrap();
        let loss = if tr.diverged || !ev.lm_loss.is_finite() {
            "inf (diverged)".to_string()
        } else {
            format!("{:.3}", ev.lm_loss)
        };
        rows.push(vec![name.to_string(), loss]);
    }
    report.table(&["Loss fn", "LM Loss"], &rows);
    report.finish();
}
