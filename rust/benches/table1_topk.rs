//! Table 1: vanilla Top-K KD sweep — LM loss, % CE->FullKD, ECE vs K,
//! plus the Top-p row. Expectation (paper §2.1): small K underperforms CE,
//! ECE worsens as K shrinks, FullKD is the ceiling.

use rskd::coordinator::pct_ce_to_fullkd;
use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(mut pipe) = expt::prepare_small("table1") else { return };

    let mut report = Report::new("table1_topk", "Vanilla Top-K KD (paper Table 1)");
    let mut rows = Vec::new();

    let (_, _, ev_ce) = pipe.run_spec(&expt::spec("ce"), 3).unwrap();
    let (_, _, ev_fk) = pipe.run_spec(&expt::spec("fullkd"), 3).unwrap();

    rows.push(vec!["CE".into(), format!("{:.3}", ev_ce.lm_loss), "0%".into(),
                   format!("{:.1}", ev_ce.ece_pct)]);
    // every k shares the one Top-K cache via the pipeline's registry
    for k in [3usize, 5, 12, 25, 50] {
        let (_, _, ev) = pipe.run_spec(&expt::spec(&format!("topk:k={k}")), 3).unwrap();
        rows.push(vec![
            format!("{k}"),
            format!("{:.3}", ev.lm_loss),
            format!("{:.0}%", pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)),
            format!("{:.1}", ev.ece_pct),
        ]);
    }
    // the paper's *50 row: Top-p 0.98 capped at K=50
    let (_, _, ev) = pipe.run_spec(&expt::spec("topp:p=0.98,k=50"), 3).unwrap();
    rows.push(vec![
        "*50 (top-p .98)".into(),
        format!("{:.3}", ev.lm_loss),
        format!("{:.0}%", pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)),
        format!("{:.1}", ev.ece_pct),
    ]);
    rows.push(vec!["FullKD".into(), format!("{:.3}", ev_fk.lm_loss), "100%".into(),
                   format!("{:.1}", ev_fk.ece_pct)]);

    report.table(&["Unique Tokens", "LM Loss", "% CE to FullKD", "ECE %"], &rows);
    report.finish();
}
