//! Table 11: adapting the teacher to a shifted data distribution before
//! distilling (paper: Llama-3-8B on Fineweb-edu). The teacher pre-trains on
//! corpus A; the student trains on shifted corpus B. Expectation: KD without
//! adaptation barely beats CE; a short teacher fine-tune on B recovers the
//! KD gain.

use rskd::coordinator::Pipeline;
use rskd::data::TextDataset;
use rskd::expt;
use rskd::report::Report;

fn main() {
    if !expt::artifacts_exist("artifacts/small") {
        println!("[skipped: artifacts/small missing]");
        return;
    }
    // teacher domain A = default corpus; student domain B = shifted corpus.
    // Domain-B pipelines get teacher_steps=1 (their built-in teacher is
    // replaced by the domain-A teacher below) to avoid wasted pre-training.
    let mut cfg = expt::config_for("artifacts/small", "table11");
    cfg.corpus = cfg.corpus.shifted(); // pipeline data (student domain) = B
    cfg.teacher_steps = 1;
    let mut pipe = Pipeline::prepare(cfg.clone()).unwrap();

    // the real teacher: pre-trained on domain A only
    let cfg_a = expt::config_for("artifacts/small", "table11-A");
    let pipe_a = Pipeline::prepare(cfg_a).unwrap();
    let teacher_a = pipe_a.teacher.clone();

    let mut report = Report::new("table11_adapt", "Teacher adaptation (paper Table 11)");
    let mut rows = Vec::new();

    let (_, _, ev_ce, z_ce) = expt::run_with_zero_shot(&mut pipe, &expt::spec("ce"), 3).unwrap();
    rows.push(vec!["CE".into(), format!("{:.3}", ev_ce.lm_loss), format!("{z_ce:.1}")]);

    let rs12 = expt::spec("rs:rounds=12");

    // KD w/o adaptation: cache built by the domain-A teacher over domain-B data
    {
        let mut unadapted_pipe = Pipeline::prepare(cfg.clone()).unwrap();
        unadapted_pipe.teacher = teacher_a.clone();
        // defensive: the registry is empty on a fresh pipeline, but the
        // teacher-swap-then-clear idiom keeps this correct if caches are
        // ever warmed before the swap
        unadapted_pipe.clear_caches();
        let (_, _, ev, z) = expt::run_with_zero_shot(&mut unadapted_pipe, &rs12, 3).unwrap();
        rows.push(vec!["KD w/o adapt".into(), format!("{:.3}", ev.lm_loss), format!("{z:.1}")]);
    }

    // KD with adaptation: fine-tune the domain-A teacher briefly on B
    {
        let mut adapted_pipe = Pipeline::prepare(cfg).unwrap();
        let mut teacher = teacher_a;
        teacher.reset_optimizer();
        let ds = TextDataset::build(&adapted_pipe.cfg.corpus, adapted_pipe.engine.manifest().vocab,
                                    40_000, 31);
        adapted_pipe.continue_ce(&mut teacher, &ds.docs, expt::scale().teacher_steps / 4, 1e-4).unwrap();
        adapted_pipe.teacher = teacher;
        adapted_pipe.clear_caches(); // defensive, as above
        let (_, _, ev, z) = expt::run_with_zero_shot(&mut adapted_pipe, &rs12, 3).unwrap();
        rows.push(vec!["KD w adapt".into(), format!("{:.3}", ev.lm_loss), format!("{z:.1}")]);
    }

    report.table(&["Method", "LM Loss", "0-shot"], &rows);
    report.finish();
}
