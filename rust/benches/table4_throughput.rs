//! Table 4: training throughput — tokens/sec (relative, FullKD = 1.0x) and
//! an estimated FLOP/s column for CE vs RS-KD (cached) vs FullKD (online
//! teacher). Expectation: RS-KD within ~10% of CE; FullKD pays the online
//! teacher forward.

use rskd::expt;
use rskd::metrics::throughput::train_flops_per_token;
use rskd::report::Report;

fn main() {
    let Some(mut pipe) = expt::prepare_small("table4") else { return };
    let m = pipe.engine.manifest();
    let p_student = m.role("student").unwrap().param_count as u64;
    let p_teacher = m.role("teacher").unwrap().param_count as u64;

    // warm up compiles so the timed runs measure steady-state throughput
    pipe.engine
        .warmup(&[
            "train_ce_student",
            "train_sparse_student",
            "train_sparse_jnp_student", // CPU hot path after the perf pass
            "train_dense_student",
            "fwd_teacher",
        ])
        .unwrap();

    let runs: Vec<(&str, &str, u64)> = vec![
        ("CE", "ce", 0),
        ("Random Sampling", "rs:rounds=50", 0),
        ("Full KD", "fullkd", 2 * p_teacher),
    ];

    let mut measured = Vec::new();
    for (name, s, teacher_flops) in runs {
        let (_, tr, _) = pipe.run_spec(&expt::spec(s), 3).unwrap();
        let fpt = train_flops_per_token(p_student, 0) + teacher_flops;
        measured.push((name, tr.tokens_per_sec, fpt as f64 * tr.tokens_per_sec));
    }
    let fullkd_tps = measured.last().unwrap().1;

    let mut report = Report::new("table4_throughput", "Speed/Throughput (paper Table 4)");
    let rows: Vec<Vec<String>> = measured
        .iter()
        .map(|(name, tps, flops)| {
            vec![
                name.to_string(),
                format!("{:.2}x", tps / fullkd_tps),
                format!("{tps:.0}"),
                format!("{:.2} MFLOP/s", flops / 1e6),
            ]
        })
        .collect();
    report.table(&["Method", "Tokens/sec (rel FullKD)", "Tokens/sec", "est. FLOP/s"], &rows);
    report.line(format!(
        "(student {p_student} params, teacher {p_teacher} params; FullKD pays 2*teacher fwd FLOPs/token online)"
    ));
    report.finish();
}
