//! Table 8: LLM-as-judge on generative instruction-following tasks.
//! Paper used Llama-3.1-405B as judge on Dolly/SelfInst/Vicuna/S-NI/UnNI;
//! offline we use the teacher as judge on five synthetic instruction
//! datasets (DESIGN.md §4 substitution). Expectation: RS-KD wins the average.

use rskd::data::TextDataset;
use rskd::evalsuite::judge_scores;
use rskd::expt;
use rskd::report::Report;
use rskd::util::rng::Pcg;

fn main() {
    let Some(mut pipe) = expt::prepare_small("table8") else { return };
    let m = pipe.engine.manifest();

    // five synthetic instruction datasets (stand-ins for Dolly/SelfInst/...)
    let ds = TextDataset::build(&pipe.cfg.corpus, m.vocab, 4_000, 21);
    let mut rng = Pcg::new(77);
    let datasets: Vec<(String, Vec<(Vec<u32>, Vec<u32>)>)> = ["Dolly*", "SelfInst*", "Vicuna*", "S-NI*", "UnNI*"]
        .iter()
        .map(|name| {
            let corpus = rskd::data::corpus::Corpus::build(&pipe.cfg.corpus);
            let pairs: Vec<(Vec<u32>, Vec<u32>)> = (0..2 * m.batch)
                .map(|_| {
                    let (p, r) = corpus.gen_instruction_doc(&mut rng);
                    let mut prompt = ds.bpe.encode(&format!("Q: {p} A:"));
                    let mut resp = ds.bpe.encode(&r);
                    prompt.truncate(m.seq / 2);
                    resp.truncate(m.seq / 4);
                    (prompt, resp)
                })
                .collect();
            (name.to_string(), pairs)
        })
        .collect();

    let runs: Vec<(&str, &str)> = vec![
        ("CE", "ce"),
        ("Top-K 12", "topk:k=12"),
        ("Top-K 50", "topk:k=50"),
        ("Ours 12", "rs:rounds=12"),
        ("FullKD", "fullkd"),
    ];

    let mut report = Report::new("table8_judge", "LLM-as-judge generative eval (paper Table 8)");
    let mut per_method = Vec::new();
    for (name, s) in runs {
        let (mut student, _, _) = pipe.run_spec(&expt::spec(s), 3).unwrap();
        // brief SFT before generation (the paper judges instruction-tuned models)
        student.reset_optimizer();
        let sft_docs = TextDataset::build_sft_docs(&pipe.cfg.corpus, &ds.bpe, 40, 9);
        pipe.continue_ce(&mut student, &sft_docs, 15, 2e-5).unwrap();
        let rep = judge_scores(&pipe.engine, &student, &pipe.teacher, &datasets, m.seq / 4).unwrap();
        per_method.push((name, rep));
    }

    let mut header: Vec<&str> = vec!["Dataset"];
    for (n, _) in &per_method {
        header.push(n);
    }
    let mut rows = Vec::new();
    for (di, (dname, _)) in datasets.iter().enumerate() {
        let mut row = vec![dname.clone()];
        for (_, rep) in &per_method {
            row.push(format!("{:.1}", rep.scores[di].1));
        }
        rows.push(row);
    }
    let mut avg = vec!["Avg".to_string()];
    for (_, rep) in &per_method {
        avg.push(format!("{:.1}", rep.average));
    }
    rows.push(avg);
    report.table(&header, &rows);
    report.finish();
}
