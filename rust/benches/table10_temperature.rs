//! Table 10: proposal temperature ablation — RS-KD with q ∝ p^t for
//! t ∈ {0, 0.8, 1.0, 1.2}. Expectation: t=0 (uniform proposal) diverges;
//! t ∈ [0.8, 1.2] all comparable.

use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(mut pipe) = expt::prepare_small("table10") else { return };
    let mut report = Report::new("table10_temperature", "Proposal temperature (paper Table 10)");
    let mut rows = Vec::new();
    for temp in [0.0f32, 0.8, 1.0, 1.2] {
        // each temperature is its own cache plan, so the registry builds one
        // cache per row (and would reuse them on a re-run within the process)
        let spec = expt::spec(&format!("rs:rounds=50,temp={temp}"));
        let handle = pipe.ensure_cache(&spec).unwrap().unwrap();
        let (_, tr, ev) = pipe.run_spec(&spec, 3).unwrap();
        if tr.diverged || !ev.lm_loss.is_finite() || ev.lm_loss > 20.0 {
            rows.push(vec![format!("{temp}"), format!("{:.1}", handle.stats.avg_unique_tokens),
                           "inf (diverged)".into(), "-".into(), "-".into()]);
        } else {
            rows.push(vec![
                format!("{temp}"),
                format!("{:.1}", handle.stats.avg_unique_tokens),
                format!("{:.3}", ev.lm_loss),
                format!("{:.1}", ev.ece_pct),
                format!("{:.1}", ev.spec_accept_pct),
            ]);
        }
    }
    report.table(&["Sample Temp", "Unique Tokens", "LM Loss", "ECE %", "SpecAccept %"], &rows);
    report.finish();
}
