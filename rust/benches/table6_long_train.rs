//! Table 6: effect of longer training (paper: 100B tokens = 16x Chinchilla)
//! — CE vs RS-KD (12 tokens) vs FullKD at 4x the standard step budget.
//! Expectation: all three converge to similar LM loss; RS keeps calibration.

use rskd::coordinator::Pipeline;
use rskd::expt;
use rskd::report::{Report, METRIC_HEADER};

fn main() {
    if !expt::artifacts_exist("artifacts/small") {
        println!("[skipped: artifacts/small missing]");
        return;
    }
    let mut cfg = expt::config_for("artifacts/small", "table6");
    cfg.student_steps *= 3; // "longer training" regime
    let mut pipe = Pipeline::prepare(cfg).unwrap();

    let mut report = Report::new("table6_long_train", "Longer training (paper Table 6)");
    let mut rows = Vec::new();
    for (name, s) in [
        ("CE", "ce"),
        ("Ours (RS-12)", "rs:rounds=12"),
        ("FullKD", "fullkd"),
    ] {
        let (_, _, ev, z) = expt::run_with_zero_shot(&mut pipe, &expt::spec(s), 3).unwrap();
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            "-".into(),
            format!("{z:.1}"),
        ]);
    }
    report.table(&METRIC_HEADER, &rows);
    report.finish();
}
