//! Table 6: effect of longer training (paper: 100B tokens = 16x Chinchilla)
//! — CE vs RS-KD (12 tokens) vs FullKD at 4x the standard step budget.
//! Expectation: all three converge to similar LM loss; RS keeps calibration.

use rskd::coordinator::{CacheKind, Pipeline, StudentMethod};
use rskd::expt;
use rskd::report::{Report, METRIC_HEADER};

fn main() {
    if !expt::artifacts_exist("artifacts/small") {
        println!("[skipped: artifacts/small missing]");
        return;
    }
    let mut cfg = expt::config_for("artifacts/small", "table6");
    cfg.student_steps *= 3; // "longer training" regime
    let pipe = Pipeline::prepare(cfg).unwrap();
    let (cache, _) = pipe.build_cache(CacheKind::Rs { rounds: 12, temp: 1.0 }, "t6", 1).unwrap();

    let mut report = Report::new("table6_long_train", "Longer training (paper Table 6)");
    let mut rows = Vec::new();
    for (name, method, cache_ref) in [
        ("CE", StudentMethod::Ce, None),
        ("Ours (RS-12)", expt::rs(), Some(&cache)),
        ("FullKD", StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None),
    ] {
        let (_, _, ev, z) = expt::run_with_zero_shot(&pipe, &method, cache_ref, 3).unwrap();
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            "-".into(),
            format!("{z:.1}"),
        ]);
    }
    report.table(&METRIC_HEADER, &rows);
    report.finish();
}
