//! Table 5: Random Sampling KD sweep over sampling budget — measured unique
//! tokens, LM loss, ECE, speculative accept, 0-shot. Expectation: ~12 unique
//! tokens already matches FullKD loss and calibration.

use rskd::expt;
use rskd::report::{Report, METRIC_HEADER};

fn main() {
    let Some(mut pipe) = expt::prepare_small("table5") else { return };
    let mut report = Report::new("table5_rskd", "Random Sampling KD sweep (paper Table 5)");
    let mut rows = Vec::new();

    let (_, _, ev_ce, z_ce) = expt::run_with_zero_shot(&mut pipe, &expt::spec("ce"), 3).unwrap();
    rows.push(vec!["CE".into(), format!("{:.3}", ev_ce.lm_loss), format!("{:.1}", ev_ce.ece_pct),
                   format!("{:.1}", ev_ce.spec_accept_pct), "-".into(), format!("{z_ce:.1}")]);

    for rounds in [2u32, 5, 12, 25, 50] {
        let spec = expt::spec(&format!("rs:rounds={rounds}"));
        report.meta(&format!("rs{rounds}"), spec.to_json());
        // build (or fetch) this budget's cache up front for its stats column
        let handle = pipe.ensure_cache(&spec).unwrap().unwrap();
        let (_, _, ev, z) = expt::run_with_zero_shot(&mut pipe, &spec, 3).unwrap();
        rows.push(vec![
            format!("{:.1}", handle.stats.avg_unique_tokens),
            format!("{:.3}", ev.lm_loss),
            format!("{:.1}", ev.ece_pct),
            format!("{:.1}", ev.spec_accept_pct),
            "-".into(),
            format!("{z:.1}"),
        ]);
    }
    let (_, _, ev_fk, z_fk) =
        expt::run_with_zero_shot(&mut pipe, &expt::spec("fullkd"), 3).unwrap();
    rows.push(vec!["FullKD".into(), format!("{:.3}", ev_fk.lm_loss), format!("{:.1}", ev_fk.ece_pct),
                   format!("{:.1}", ev_fk.spec_accept_pct), "-".into(), format!("{z_fk:.1}")]);

    report.table(&METRIC_HEADER, &rows);
    report.finish();
}
