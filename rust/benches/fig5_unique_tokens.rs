//! Figure 5 (+ Appendix C): unique tokens sampled vs sampling rounds on the
//! Zipf synthetic teacher, with a log-log power-law fit. Expectation: almost
//! perfectly linear in log-log (R^2 > 0.99).

use rskd::metrics::powerlaw::fit_powerlaw;
use rskd::report::Report;
use rskd::sampling::rounds::{rounds_curve, rounds_for_unique};
use rskd::sampling::zipf::zipf;

fn main() {
    let p = zipf(512, 1.0);
    let rounds = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    let curve = rounds_curve(&p, &rounds, 120, 0);

    let mut report = Report::new("fig5_unique_tokens", "Unique tokens vs sampling rounds (paper Figure 5)");
    let rows: Vec<Vec<String>> = curve
        .iter()
        .map(|(n, u)| vec![format!("{n}"), format!("{u:.2}")])
        .collect();
    report.table(&["sampling rounds", "avg unique tokens"], &rows);

    let pts: Vec<(f64, f64)> = curve.iter().map(|&(n, u)| (n as f64, u)).collect();
    let fit = fit_powerlaw(&pts);
    report.line(format!(
        "power-law fit: unique ≈ {:.2} * rounds^{:.3}  (R² = {:.4})",
        fit.scale, fit.exponent, fit.r2
    ));

    for target in [12.0f64, 25.0, 50.0] {
        let n = rounds_for_unique(&p, target, 60, 1);
        report.line(format!("rounds for ~{target} unique tokens: {n}"));
    }
    report.finish();
}
