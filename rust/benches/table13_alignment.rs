//! Table 13 (Appendix D.3): teacher/student sequence alignment — the cache
//! is addressed in the teacher packing's position space; a student that
//! re-packs the same documents with a different shuffle seed reads
//! misaligned targets. Expectation: same-seed offline ~= online; different
//! seeds lose a chunk of the KD gain.

use rskd::coordinator::{pct_ce_to_fullkd, Pipeline};
use rskd::expt;
use rskd::report::Report;

fn main() {
    if !expt::artifacts_exist("artifacts/small") {
        println!("[skipped: artifacts/small missing]");
        return;
    }
    let base = expt::config_for("artifacts/small", "table13");
    let mut pipe = Pipeline::prepare(base.clone()).unwrap();

    let (_, _, ev_ce) = pipe.run_spec(&expt::spec("ce"), 3).unwrap();
    // online = the entire teacher runs during student training (FullKD-style,
    // but sparse-equivalent: dense targets)
    let (_, _, ev_online) = pipe.run_spec(&expt::spec("fullkd"), 3).unwrap();

    let rs50 = expt::spec("rs:rounds=50");
    let mut rows = Vec::new();
    for (name, packing_seed) in
        [("Same shuffle seed", base.teacher_shuffle_seed), ("Different shuffle seed", 0xBAD)]
    {
        // the registry keeps the one RS-50 cache; only the student-side
        // packing changes, which is exactly the misalignment under test
        pipe.set_student_packing_seed(packing_seed);
        let (_, _, ev) = pipe.run_spec(&rs50, 3).unwrap();
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", ev.lm_loss),
            format!("{:.0}%", pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_online.lm_loss)),
        ]);
    }

    let mut report = Report::new("table13_alignment", "Sequence alignment (paper Table 13)");
    report.table(&["Shuffle Seeds", "LM Loss", "% CE to online"], &rows);
    report.line(format!(
        "(CE {:.3}, online KD {:.3}; cache addressed in the teacher packing)",
        ev_ce.lm_loss, ev_online.lm_loss
    ));
    report.finish();
}
