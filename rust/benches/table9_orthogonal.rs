//! Table 9: orthogonal improvements — CE-weight α × adaptive LR-ratio grid on
//! RS-KD, reported as '% CE to FullKD'. Expectation: mild CE mixing + 1.5-2x
//! hard-token LR pushes RS-KD past FullKD (>100%).

use rskd::coordinator::pct_ce_to_fullkd;
use rskd::expt;
use rskd::report::Report;
use rskd::spec::{AdaptiveLr, DistillSpec};

fn main() {
    let Some(mut pipe) = expt::prepare_small("table9") else { return };

    let (_, _, ev_ce) = pipe.run_spec(&expt::spec("ce"), 3).unwrap();
    let (_, _, ev_fk) = pipe.run_spec(&expt::spec("fullkd"), 3).unwrap();

    let alphas = [0.3f32, 0.2, 0.1, 0.0];
    let ratios = [1.0f32, 1.5, 2.0];
    let mut report = Report::new("table9_orthogonal", "CE weight x LR ratio grid (paper Table 9)");
    let mut rows = Vec::new();
    for &ratio in &ratios {
        let mut row = vec![format!("LR {ratio}")];
        for &alpha in &alphas {
            // the grid cell as a spec: the builder helpers compose the same
            // objects the `rs:rounds=12,alpha=..,adapt=..` grammar parses to
            let mut spec = DistillSpec::rs(12).with_alpha(alpha);
            if ratio > 1.0 {
                spec = spec.with_adaptive(AdaptiveLr { ratio, hard_frac: 0.5 });
            }
            let (_, _, ev) = pipe.run_spec(&spec, 3).unwrap();
            row.push(format!(
                "{:.0}",
                pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)
            ));
        }
        rows.push(row);
    }
    let header: Vec<String> =
        std::iter::once("LR Ratio \\ alpha".to_string()).chain(alphas.iter().map(|a| format!("{a}"))).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    report.table(&header_refs, &rows);
    report.line(format!("(CE loss {:.3}, FullKD loss {:.3})", ev_ce.lm_loss, ev_fk.lm_loss));
    report.finish();
}
