//! Table 9: orthogonal improvements — CE-weight α × adaptive LR-ratio grid on
//! RS-KD, reported as '% CE to FullKD'. Expectation: mild CE mixing + 1.5-2x
//! hard-token LR pushes RS-KD past FullKD (>100%).

use rskd::coordinator::trainer::{AdaptiveLr, SparseVariant};
use rskd::coordinator::{pct_ce_to_fullkd, CacheKind, StudentMethod};
use rskd::expt;
use rskd::report::Report;

fn main() {
    let Some(pipe) = expt::prepare_small("table9") else { return };
    let (cache, _) = pipe.build_cache(CacheKind::Rs { rounds: 12, temp: 1.0 }, "t9", 1).unwrap();

    let (_, _, ev_ce) = pipe.run_student(&rskd::coordinator::StudentMethod::Ce, None, 3).unwrap();
    let (_, _, ev_fk) = pipe
        .run_student(&StudentMethod::DenseOnline { kind: "kld", alpha: 0.0 }, None, 3)
        .unwrap();

    let alphas = [0.3f32, 0.2, 0.1, 0.0];
    let ratios = [1.0f32, 1.5, 2.0];
    let mut report = Report::new("table9_orthogonal", "CE weight x LR ratio grid (paper Table 9)");
    let mut rows = Vec::new();
    for &ratio in &ratios {
        let mut row = vec![format!("LR {ratio}")];
        for &alpha in &alphas {
            let adaptive =
                (ratio > 1.0).then_some(AdaptiveLr { ratio, hard_frac: 0.5 });
            let method = StudentMethod::Sparse { variant: SparseVariant::Rs, alpha, adaptive };
            let (_, _, ev) = pipe.run_student(&method, Some(&cache), 3).unwrap();
            row.push(format!(
                "{:.0}",
                pct_ce_to_fullkd(ev.lm_loss, ev_ce.lm_loss, ev_fk.lm_loss)
            ));
        }
        rows.push(row);
    }
    let header: Vec<String> =
        std::iter::once("LR Ratio \\ alpha".to_string()).chain(alphas.iter().map(|a| format!("{a}"))).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    report.table(&header_refs, &rows);
    report.line(format!("(CE loss {:.3}, FullKD loss {:.3})", ev_ce.lm_loss, ev_fk.lm_loss));
    report.finish();
}
