//! Integration tests for the sharded cache cluster (`rskd::cluster`):
//! in-process multi-server fleets over unix sockets, asserting the three
//! cluster contracts end to end —
//!
//! 1. a 3-server range-partitioned cluster is byte-identical to a single
//!    `CacheReader` over the same directory (including shard-spanning and
//!    past-the-end ranges);
//! 2. killing one replica of a hot shard loses no requests (failover to the
//!    surviving replica);
//! 3. a mid-run rebalance (epoch bump) completes with zero stale reads:
//!    every accepted response carries the new epoch, stale answers are
//!    rejected and re-routed.
//!
//! Plus wire-level checks of the epoch protocol (`WrongEpoch` frames,
//! `GetCluster` on members vs standalone servers).

use std::path::PathBuf;
use std::sync::Arc;

use rskd::cache::{CacheReader, CacheWriter, ProbCodec, RangeBlock, SparseTarget, TargetSource};
use rskd::cluster::{partition, rotate, ClusterControl, ClusterManifest, ClusterReader, ShardSpec};
use rskd::serve::{Endpoint, RangeRead, ServeClient, ServeConfig, Server, NO_EPOCH};

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-cluster-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn target_for(pos: u64) -> SparseTarget {
    SparseTarget {
        ids: vec![pos as u32 % 97, 200 + (pos as u32 % 7), 400],
        probs: vec![20.0 / 50.0, 10.0 / 50.0, 5.0 / 50.0],
    }
}

/// `n` positions in shards of 16, tagged as an RS-50 cache.
fn build_cache(dir: &std::path::Path, n: u64) {
    let w = CacheWriter::create_with_kind(
        dir,
        ProbCodec::Count { rounds: 50 },
        16,
        32,
        Some("rs:rounds=50,temp=1".into()),
    )
    .unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();
}

fn sock(dir: &std::path::Path, i: usize) -> Endpoint {
    Endpoint::Unix(dir.join(format!("m{i}.sock")))
}

/// Start one cluster member: its own `CacheReader` over the shared
/// directory, its own control. Returns `(server, control)`.
fn start_member(
    dir: &std::path::Path,
    manifest: &ClusterManifest,
    me: Endpoint,
) -> (Server, Arc<ClusterControl>) {
    let reader = Arc::new(CacheReader::open(dir).unwrap());
    let control = Arc::new(ClusterControl::new(manifest.clone(), me.clone()));
    let server =
        Server::start_cluster(reader, me, ServeConfig::default(), Arc::clone(&control)).unwrap();
    (server, control)
}

#[test]
fn three_server_cluster_byte_identical_to_single_reader() {
    let dir = tdir("ident");
    build_cache(&dir, 400);
    let eps: Vec<Endpoint> = (0..3).map(|i| sock(&dir, i)).collect();
    let manifest = partition(400, &eps).unwrap();
    let _members: Vec<(Server, Arc<ClusterControl>)> =
        eps.iter().map(|ep| start_member(&dir, &manifest, ep.clone())).collect();

    // bootstrap from a single seed member (GetCluster + GetManifest)
    let cluster = ClusterReader::connect(&eps[1]).unwrap();
    assert_eq!(cluster.manifest_epoch(), 1);
    assert_eq!(TargetSource::positions(&cluster), 400);
    assert_eq!(cluster.cache_kind().unwrap().to_string(), "rs:rounds=50,temp=1");

    let direct = CacheReader::open(&dir).unwrap();
    // in-shard, shard-spanning, whole-keyspace, tail-into-empty, and fully
    // past-the-end ranges — all must match a local reader byte-for-byte
    let sweep: &[(u64, usize)] =
        &[(0, 40), (120, 60), (100, 300), (0, 400), (390, 40), (400, 8), (1000, 4), (7, 1)];
    for &(start, len) in sweep {
        let routed = cluster.try_get_range(start, len).unwrap();
        let local = direct.get_range(start, len);
        assert_eq!(routed, local, "range [{start}, +{len}) must be byte-identical");
    }
    // the zero-allocation path answers the same bytes as the vec path
    let mut block = RangeBlock::new();
    TargetSource::read_range_into(&cluster, 100, 300, &mut block).unwrap();
    assert_eq!(block.to_targets(), direct.get_range(100, 300));

    let counters = cluster.counters();
    assert!(counters.requests >= sweep.len() as u64, "{counters:?}");
    assert_eq!(counters.stale_rejected, 0, "no rebalance ran: {counters:?}");
    assert_eq!(counters.failovers, 0, "every member stayed up: {counters:?}");
    // the whole-keyspace reads touched every member
    assert_eq!(cluster.served_by().len(), 3, "{:?}", cluster.served_by());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn replica_failover_loses_no_requests() {
    let dir = tdir("failover");
    build_cache(&dir, 320);
    let (a, b, c) = (sock(&dir, 0), sock(&dir, 1), sock(&dir, 2));
    // shard 0 is "hot": replicated on A and B; shard 1 only on C
    let manifest = ClusterManifest::new(
        1,
        vec![
            ShardSpec { lo: 0, hi: 200, endpoints: vec![a.clone(), b.clone()] },
            ShardSpec { lo: 200, hi: 320, endpoints: vec![c.clone()] },
        ],
    )
    .unwrap();
    let (_sa, _ca) = start_member(&dir, &manifest, a);
    let (sb, _cb) = start_member(&dir, &manifest, b);
    let (_sc, _cc) = start_member(&dir, &manifest, c);

    let cluster = ClusterReader::from_manifest(manifest).unwrap();
    let direct = CacheReader::open(&dir).unwrap();

    // with both replicas up, round-robin spreads the hot range across them
    for i in 0..8u64 {
        let start = (i * 13) % 150;
        assert_eq!(cluster.try_get_range(start, 40).unwrap(), direct.get_range(start, 40));
    }
    let warm = cluster.counters();
    assert!(warm.replica_served > 0, "round-robin must use the replica: {warm:?}");
    assert_eq!(warm.failovers, 0, "{warm:?}");

    // kill replica B mid-run: every subsequent hot-range request must still
    // succeed (failover to A) — degraded latency, zero lost requests
    drop(sb);
    for i in 0..16u64 {
        let start = (i * 11) % 150;
        assert_eq!(
            cluster.try_get_range(start, 40).unwrap(),
            direct.get_range(start, 40),
            "request after replica death must be served by the survivor"
        );
    }
    let after = cluster.counters();
    assert!(after.failovers > 0, "the dead replica must have been skipped: {after:?}");
    assert_eq!(after.stale_rejected, 0, "failover is not an epoch event: {after:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn rebalance_epoch_bump_zero_stale_reads() {
    let dir = tdir("rebalance");
    build_cache(&dir, 256);
    let eps: Vec<Endpoint> = (0..2).map(|i| sock(&dir, i)).collect();
    let manifest = partition(256, &eps).unwrap();
    let members: Vec<(Server, Arc<ClusterControl>)> =
        eps.iter().map(|ep| start_member(&dir, &manifest, ep.clone())).collect();

    let cluster = ClusterReader::from_manifest(manifest.clone()).unwrap();
    let direct = CacheReader::open(&dir).unwrap();
    assert_eq!(cluster.try_get_range(0, 256).unwrap(), direct.get_range(0, 256));
    assert_eq!(cluster.manifest_epoch(), 1);

    // mid-run rebalance: every shard changes owner, epoch 1 -> 2; the test
    // applies it straight to the members' controls (the CLI's manifest-file
    // poller is just another caller of the same update path)
    let rotated = rotate(&manifest).unwrap();
    for (_, control) in &members {
        control.update(rotated.clone()).unwrap();
    }

    // the reader still holds the epoch-1 map: its next pinned request must
    // be refused, the manifest refetched, and the read completed under
    // epoch 2 with identical bytes — stale data is never accepted
    for &(start, len) in &[(0u64, 96usize), (64, 128), (0, 256), (200, 80)] {
        assert_eq!(
            cluster.try_get_range(start, len).unwrap(),
            direct.get_range(start, len),
            "range [{start}, +{len}) after rebalance"
        );
    }
    assert_eq!(cluster.manifest_epoch(), 2, "reader must finish on the new epoch");
    let counters = cluster.counters();
    assert!(counters.stale_rejected >= 1, "the bump must have been observed: {counters:?}");
    assert!(counters.refetches >= 1, "{counters:?}");

    // server-side observability agrees: WrongEpoch answers were counted and
    // both members now serve (and stamp stats with) epoch 2
    let snaps: Vec<_> = members.iter().map(|(s, _)| s.stats_snapshot()).collect();
    assert!(snaps.iter().any(|s| s.wrong_epoch > 0), "no member refused the stale pin");
    assert!(snaps.iter().all(|s| s.epoch == 2), "all members must report epoch 2");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wire_level_epoch_protocol() {
    let dir = tdir("wire");
    build_cache(&dir, 200);
    let eps: Vec<Endpoint> = (0..2).map(|i| sock(&dir, i)).collect();
    let manifest = partition(200, &eps).unwrap();
    // member 0 owns [0, 100); member 1 owns [100, 200)
    let (_s0, _c0) = start_member(&dir, &manifest, eps[0].clone());

    let mut client = ServeClient::connect(&eps[0]).unwrap();
    let mut block = RangeBlock::new();

    // correctly pinned owned range: targets stamped with the epoch (the v4
    // timing echo rides along; its values are wall-clock, not asserted)
    let r = client.read_range_at(10, 20, 1, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { epoch: 1, .. }), "{r:?}");
    assert_eq!(block.len(), 20);
    // stale pin on an owned range: typed WrongEpoch carrying the current epoch
    assert_eq!(
        client.read_range_at(10, 20, 99, &mut block).unwrap(),
        RangeRead::WrongEpoch { epoch: 1 }
    );
    assert!(block.is_empty(), "WrongEpoch must leave the block cleared");
    // unpinned probe: epoch check skipped, ownership still enforced
    let r = client.read_range_at(10, 20, NO_EPOCH, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { epoch: 1, .. }), "{r:?}");
    assert_eq!(
        client.read_range_at(150, 20, NO_EPOCH, &mut block).unwrap(),
        RangeRead::WrongEpoch { epoch: 1 },
        "member 0 does not own [100, 200)"
    );
    // a member serves its shard map over the wire
    assert_eq!(client.cluster_manifest().unwrap(), manifest);
    assert_eq!(client.manifest().unwrap().epoch, 1);

    // a standalone server: no epochs anywhere, GetCluster is a typed error
    let sdir = tdir("wire-standalone");
    build_cache(&sdir, 64);
    let reader = Arc::new(CacheReader::open(&sdir).unwrap());
    let server = Server::start(
        reader,
        Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0))),
        ServeConfig::default(),
    )
    .unwrap();
    let mut lone = ServeClient::connect(server.endpoint()).unwrap();
    let err = lone.cluster_manifest().unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput, "{err}");
    assert_eq!(lone.manifest().unwrap().epoch, NO_EPOCH);
    let r = lone.read_range_at(0, 8, NO_EPOCH, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { epoch: NO_EPOCH, .. }), "{r:?}");
    // pinning an epoch at a standalone server is meaningless but answered
    // (NO_EPOCH servers admit everything; the response carries NO_EPOCH)
    let r = lone.read_range_at(0, 8, 7, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { epoch: NO_EPOCH, .. }), "{r:?}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&sdir);
}
