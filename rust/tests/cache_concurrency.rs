//! Integration tests for the v2 cache concurrency surface: multi-producer
//! out-of-order writes, lazy opening, LRU eviction, shard-boundary ranges,
//! and legacy v1 compatibility.

use std::path::PathBuf;

use rskd::cache::quant::{self, ProbCodec};
use rskd::cache::{CacheReader, CacheWriter, SparseTarget};
use rskd::util::json::Json;

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn target_for(pos: u64) -> SparseTarget {
    // deterministic per-position target so any producer can build it
    SparseTarget {
        ids: vec![pos as u32 % 97, 200 + (pos as u32 % 7), 400],
        probs: vec![20.0 / 50.0, 10.0 / 50.0, 5.0 / 50.0],
    }
}

#[test]
fn multi_producer_out_of_order_reassembles() {
    let dir = tdir("mp");
    let n = 256u64;
    let n_producers = 4u64;
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 32, 16).unwrap();
    std::thread::scope(|s| {
        for p in 0..n_producers {
            let w = &w;
            // strided interleave: every producer writes into every shard,
            // so no shard can complete from a single producer's stream
            s.spawn(move || {
                for pos in (p..n).step_by(n_producers as usize) {
                    assert!(w.push(pos, target_for(pos)));
                }
            });
        }
    });
    let stats = w.finish().unwrap();
    assert_eq!(stats.positions, n);
    assert_eq!(stats.shards, 8); // 256 / 32

    let r = CacheReader::open(&dir).unwrap();
    assert_eq!(r.positions, n);
    assert_eq!(r.shard_count(), 8);
    for pos in 0..n {
        let t = r.get(pos).unwrap_or_else(|| panic!("position {pos} missing"));
        assert_eq!(t.ids, target_for(pos).ids, "wrong target at {pos}");
    }
    assert!(r.get(n).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lru_eviction_still_serves_correct_targets() {
    let dir = tdir("lru");
    let n = 160u64; // 10 shards of 16
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 16, 8).unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();

    let r = CacheReader::open_with_capacity(&dir, 3).unwrap();
    // a shard-hostile access pattern: stride the whole stream repeatedly
    for round in 0..4u64 {
        for pos in (round..n).step_by(16) {
            let t = r.get(pos).unwrap();
            assert_eq!(t.ids, target_for(pos).ids);
        }
        assert!(r.resident_shards() <= 3, "LRU exceeded its capacity");
    }
    assert!(r.shard_loads() > 10, "expected eviction churn under capacity 3");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn get_range_spans_shard_boundary() {
    let dir = tdir("boundary");
    let n = 64u64;
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 16, 8).unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();

    let r = CacheReader::open(&dir).unwrap();
    // [8, 40): crosses the 16 and 32 shard boundaries
    let ts = r.get_range(8, 32);
    assert_eq!(ts.len(), 32);
    for (i, t) in ts.iter().enumerate() {
        assert_eq!(t.ids, target_for(8 + i as u64).ids, "wrong target at offset {i}");
    }
    // exactly the three overlapped shards were decoded
    assert_eq!(r.shard_loads(), 3);
    // past-the-end tail pads with empty targets
    let tail = r.get_range(n - 2, 5);
    assert_eq!(tail[0].k(), 3);
    assert_eq!(tail[1].k(), 3);
    assert!(tail[2..].iter().all(|t| t.k() == 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn open_is_lazy_until_first_touch() {
    let dir = tdir("lazy");
    let w = CacheWriter::create(&dir, ProbCodec::Ratio, 16, 8).unwrap();
    for pos in 0..128u64 {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();

    let r = CacheReader::open(&dir).unwrap();
    assert_eq!(r.shard_count(), 8);
    assert_eq!(r.resident_shards(), 0, "open must not decode shard records");
    assert_eq!(r.shard_loads(), 0);
    let _ = r.get_range(48, 16); // one shard's worth
    assert_eq!(r.shard_loads(), 1, "touching one shard must load exactly one");
    assert_eq!(r.resident_shards(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Hand-write a legacy v1 cache directory: "SLC1" shards named in stream
/// order plus a totals-only `cache.json` — exactly what the pre-v2 writer
/// produced. The lazy reader must open it from headers alone.
#[test]
fn legacy_v1_cache_opens_correctly() {
    let dir = tdir("v1");
    std::fs::create_dir_all(&dir).unwrap();
    let n = 40u64;
    let per_shard = 16u64;
    let mut bytes = 0u64;
    let mut slots = 0u64;
    let mut shard_no = 0u32;
    let mut pos = 0u64;
    while pos < n {
        let count = per_shard.min(n - pos);
        let mut buf = Vec::new();
        buf.extend_from_slice(&0x534C_4331u32.to_le_bytes()); // "SLC1"
        buf.extend_from_slice(&[2u8, 50, 0, 0]); // codec Count, rounds 50
        buf.extend_from_slice(&pos.to_le_bytes());
        buf.extend_from_slice(&count.to_le_bytes());
        for p in pos..pos + count {
            let t = target_for(p);
            buf.push(t.ids.len() as u8);
            for (&id, &prob) in t.ids.iter().zip(t.probs.iter()) {
                let code = (prob * 50.0).round() as u8;
                buf.extend_from_slice(&quant::pack_slot(id, code));
            }
            slots += t.ids.len() as u64;
        }
        bytes += buf.len() as u64;
        std::fs::write(dir.join(format!("shard-{shard_no:05}.slc")), &buf).unwrap();
        shard_no += 1;
        pos += count;
    }
    let meta = Json::obj(vec![
        ("codec", Json::num(2.0)),
        ("rounds", Json::num(50.0)),
        ("positions", Json::num(n as f64)),
        ("slots", Json::num(slots as f64)),
        ("bytes", Json::num(bytes as f64)),
        ("shards", Json::num(shard_no as f64)),
    ]);
    std::fs::write(dir.join("cache.json"), meta.to_string()).unwrap();

    let r = CacheReader::open(&dir).unwrap();
    assert_eq!(r.version, 1);
    assert_eq!(r.positions, n);
    assert_eq!(r.rounds, 50);
    assert_eq!(r.resident_shards(), 0, "v1 open must also be lazy");
    for p in 0..n {
        let t = r.get(p).unwrap();
        assert_eq!(t.ids, target_for(p).ids);
        assert!((t.probs[0] - 0.4).abs() < 1e-6);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_count_mismatch_is_a_clean_error() {
    let dir = tdir("corrupt");
    let w = CacheWriter::create(&dir, ProbCodec::Ratio, 16, 8).unwrap();
    for pos in 0..32u64 {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();
    // inflate the first shard's declared count past its record count
    let idx = dir.join("index.json");
    let text = std::fs::read_to_string(&idx).unwrap();
    let text = text.replacen("\"count\":16", "\"count\":20", 1);
    std::fs::write(&idx, text).unwrap();

    let r = CacheReader::open(&dir).unwrap();
    let err = r.try_get(0).unwrap_err();
    assert!(err.to_string().contains("corrupt cache"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_shard_version_fails_with_clear_error() {
    let dir = tdir("badmagic");
    std::fs::create_dir_all(&dir).unwrap();
    // plausible-looking shard with a future magic ("SLC9")
    let mut buf = Vec::new();
    buf.extend_from_slice(&0x534C_4339u32.to_le_bytes());
    buf.extend_from_slice(&[2u8, 50, 0, 0]);
    buf.extend_from_slice(&0u64.to_le_bytes());
    buf.extend_from_slice(&1u64.to_le_bytes());
    buf.push(0);
    std::fs::write(dir.join("shard-00000.slc"), &buf).unwrap();
    std::fs::write(
        dir.join("cache.json"),
        Json::obj(vec![("positions", Json::num(1.0))]).to_string(),
    )
    .unwrap();

    let err = CacheReader::open(&dir).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unsupported shard magic"), "got: {msg}");
    assert!(msg.contains("SLC1") && msg.contains("SLC2"), "got: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}
