//! Integration tests for the zero-allocation assembly hot path (DESIGN.md
//! §Hot path):
//!
//! * `read_range_into` over local and served sources returns bytes identical
//!   to the legacy `get_range`, including the misaligned-packing "missing
//!   positions decode as empty" semantics across the CSR path;
//! * golden test — `assemble_sparse_block_into` (serial and parallel)
//!   produces byte-identical `idx`/`val`/`smooth`/`lr_scale` blocks to the
//!   legacy `assemble_sparse_block` for every `Variant`, over both
//!   `CacheReader` and `ServedReader`;
//! * steady-state assembly performs zero heap allocations (counting
//!   allocator installed in this binary; counts are thread-local so the
//!   parallel test harness cannot pollute them);
//! * the prefetched training loop produces the exact same `losses` sequence
//!   as the synchronous loop for a fixed seed (requires `artifacts/small`;
//!   self-skips otherwise, like `pipeline_integration`).

use std::path::PathBuf;
use std::sync::Arc;

use rskd::cache::{CacheReader, CacheWriter, MemoryTier, ProbCodec, RangeBlock, TargetSource};
use rskd::coordinator::{
    assemble_sparse_block, assemble_sparse_block_into, AssembleScratch, SparseBlock, TrainOpts,
};
use rskd::data::loader::Batch;
use rskd::sampling::random_sampling;
use rskd::sampling::zipf::zipf;
use rskd::serve::{Endpoint, ServeConfig, ServedReader, Server};
use rskd::spec::{AdaptiveLr, Variant};
use rskd::util::bench::alloc_count;
use rskd::util::rng::Pcg;

#[global_allocator]
static ALLOC: alloc_count::CountingAllocator = alloc_count::CountingAllocator;

const VOCAB: usize = 512;

/// RS-50 cache over positions [0, 64) and [96, 160) with shard span 32:
/// positions [64, 96) fall between shards — the misaligned-packing hole.
fn build_gapped_cache(dir: &std::path::Path) {
    let _ = std::fs::remove_dir_all(dir);
    let p = zipf(VOCAB, 1.0);
    let mut rng = Pcg::new(5);
    let w = CacheWriter::create(dir, ProbCodec::Count { rounds: 50 }, 32, 64).unwrap();
    for pos in (0u64..64).chain(96..160) {
        assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
    }
    w.finish().unwrap();
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rskd-hotpath-{tag}-{}", std::process::id()))
}

fn serve(reader: Arc<CacheReader>) -> (Server, ServedReader) {
    let ep = Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0)));
    let server = Server::start(reader, ep, ServeConfig::default()).unwrap();
    let served = ServedReader::connect(server.endpoint()).unwrap();
    (server, served)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn read_range_into_matches_get_range_local_and_served() {
    let dir = tmp_dir("csr");
    build_gapped_cache(&dir);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let (server, served) = serve(Arc::clone(&reader));

    let mut local_block = RangeBlock::new();
    let mut served_block = RangeBlock::new();
    // windows: inside a shard, across the hole, before position 0's shard
    // boundary effects, and padding past the last position
    for (start, len) in [(0u64, 16usize), (48, 64), (90, 20), (150, 20)] {
        let legacy = reader.get_range(start, len);
        reader.read_range_into(start, len, &mut local_block).unwrap();
        served.read_range_into(start, len, &mut served_block).unwrap();
        let served_legacy = served.try_get_range(start, len).unwrap();
        assert_eq!(local_block.len(), len);
        assert_eq!(served_block.len(), len);
        for (i, t) in legacy.iter().enumerate() {
            let ctx = format!("start {start} len {len} pos {i}");
            let (ids, probs) = local_block.get(i);
            assert_eq!(ids, t.ids.as_slice(), "{ctx}");
            assert_eq!(bits(probs), bits(&t.probs), "{ctx}");
            let (sids, sprobs) = served_block.get(i);
            assert_eq!(sids, t.ids.as_slice(), "served {ctx}");
            assert_eq!(bits(sprobs), bits(&t.probs), "served {ctx}");
            assert_eq!(&served_legacy[i], t, "served legacy {ctx}");
        }
    }
    // the hole itself: every position of [64, 96) decodes empty on all paths
    reader.read_range_into(64, 32, &mut local_block).unwrap();
    served.read_range_into(64, 32, &mut served_block).unwrap();
    for i in 0..32 {
        assert_eq!(local_block.k_of(i), 0, "hole pos {i} must decode empty");
        assert_eq!(served_block.k_of(i), 0, "served hole pos {i} must decode empty");
    }
    drop(served);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn golden_assembly_matches_legacy_for_every_variant_and_source() {
    let dir = tmp_dir("golden");
    build_gapped_cache(&dir);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let (server, served) = serve(Arc::clone(&reader));

    let (b, s, k_slots) = (4usize, 16usize, 24usize);
    let mut rng = Pcg::new(9);
    let batch = Batch {
        tokens: vec![1i32; b * s],
        labels: (0..b * s).map(|_| rng.below(VOCAB as u64) as i32).collect(),
        // rows: in-shard, across the hole, tail padding, plain
        offsets: vec![3, 56, 150, 100],
        batch: b,
        seq: s,
    };
    let variants = [
        Variant::Rs { rounds: 50, temp: 1.0 },
        Variant::TopK { k: 8, normalize: true },
        Variant::TopK { k: 8, normalize: false },
        Variant::TopP { p: 0.6, k: 12 },
        Variant::Smoothing { k: 8 },
        Variant::GhostToken { k: 8 },
        Variant::NaiveFix { k: 8 },
    ];
    let adaptives = [None, Some(AdaptiveLr { ratio: 2.0, hard_frac: 0.3 })];
    // the in-RAM tier must be assembly-transparent too (hits are memcpys of
    // the same decoded blocks)
    let tiered = MemoryTier::new(&*reader);
    let sources: [(&str, &dyn TargetSource); 3] =
        [("local", &*reader), ("served", &served), ("tiered", &tiered)];
    let mut blk = SparseBlock::default();
    for (name, source) in sources {
        for &variant in &variants {
            for &adaptive in &adaptives {
                let legacy =
                    assemble_sparse_block(source, &batch, VOCAB, k_slots, variant, adaptive);
                for workers in [1usize, 3] {
                    let mut scratch = AssembleScratch::with_workers(workers);
                    assemble_sparse_block_into(
                        source, &batch, VOCAB, k_slots, variant, adaptive, &mut scratch,
                        &mut blk,
                    )
                    .unwrap();
                    let ctx = format!("{name} {variant:?} adaptive {adaptive:?} w{workers}");
                    assert_eq!(blk.idx, legacy.idx, "{ctx}");
                    assert_eq!(bits(&blk.val), bits(&legacy.val), "{ctx}");
                    assert_eq!(bits(&blk.smooth), bits(&legacy.smooth), "{ctx}");
                    assert_eq!(bits(&blk.lr_scale), bits(&legacy.lr_scale), "{ctx}");
                    assert_eq!(blk.ghost_on, legacy.ghost_on, "{ctx}");
                }
            }
        }
    }
    drop(served);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serial_assembly_is_zero_alloc_at_steady_state() {
    assert!(
        alloc_count::is_counting(),
        "counting allocator must be installed in this test binary"
    );
    let dir = tmp_dir("alloc");
    build_gapped_cache(&dir);
    // capacity >= shard count so steady state never evicts/re-decodes
    let reader = CacheReader::open_with_capacity(&dir, 16).unwrap();
    let (b, s, k_slots) = (4usize, 16usize, 24usize);
    let batch = Batch {
        tokens: vec![1i32; b * s],
        labels: vec![7i32; b * s],
        offsets: vec![0, 40, 100, 128],
        batch: b,
        seq: s,
    };
    let variant = Variant::Rs { rounds: 50, temp: 1.0 };
    let adaptive = Some(AdaptiveLr { ratio: 2.0, hard_frac: 0.3 });
    let mut scratch = AssembleScratch::serial();
    let mut blk = SparseBlock::default();
    // warm: buffers grow to steady-state capacity, shards decode into the LRU
    for _ in 0..2 {
        assemble_sparse_block_into(
            &reader, &batch, VOCAB, k_slots, variant, adaptive, &mut scratch, &mut blk,
        )
        .unwrap();
    }
    let (allocs, _) = alloc_count::measure(|| {
        for _ in 0..3 {
            assemble_sparse_block_into(
                &reader, &batch, VOCAB, k_slots, variant, adaptive, &mut scratch, &mut blk,
            )
            .unwrap();
            std::hint::black_box(blk.val.len());
        }
    });
    assert_eq!(allocs, 0, "steady-state serial assembly must not allocate");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prefetched_loop_matches_synchronous_losses() {
    let artifacts = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/small"));
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/small not built");
        return;
    }
    use rskd::coordinator::{train_student_with, Pipeline, PipelineConfig};
    use rskd::model::ModelState;
    let cfg = PipelineConfig {
        artifact_dir: artifacts,
        target_tokens: 50_000,
        teacher_steps: 30,
        student_steps: 12,
        eval_batches: 2,
        work_dir: PathBuf::from("target/test-hotpath"),
        ..Default::default()
    };
    let steps = cfg.student_steps;
    let lr = cfg.student_lr;
    let mut pipe = Pipeline::prepare(cfg).unwrap();
    let spec = rskd::spec::DistillSpec::rs(50);
    let cache = pipe.ensure_cache(&spec).unwrap().unwrap();
    let schedule = rskd::coordinator::LrSchedule::paper_default(lr, steps);

    let mut run = |prefetch: bool| {
        let mut student = ModelState::init(&pipe.engine, "student", 3).unwrap();
        let mut loader = pipe.train_loader(11);
        train_student_with(
            &pipe.engine,
            &mut student,
            &mut loader,
            steps,
            schedule,
            &spec,
            Some(cache.reader.as_ref()),
            Some(&pipe.teacher),
            TrainOpts { prefetch, assemble_workers: 1 },
        )
        .unwrap()
    };
    let sync = run(false);
    let pre = run(true);
    assert_eq!(bits(&sync.losses), bits(&pre.losses), "prefetch must not change training");
    assert_eq!(bits(&sync.kd_losses), bits(&pre.kd_losses));
    // the overlap counters must account for every executed step
    assert_eq!(pre.prefetch_hits + pre.prefetch_misses, pre.steps as u64);
    assert!(pre.assemble_time > std::time::Duration::ZERO);
    assert_eq!(sync.prefetch_hits, 0);
    assert_eq!(sync.prefetch_misses, 0);
}
