//! Integration tests for the tiered target-source stack (DESIGN.md §Tiered
//! sources), reusing the golden-block harness style of
//! `rust/tests/trainer_hotpath.rs` (zipf RS-50 targets through the real
//! writer/reader):
//!
//! * crash-resume — interrupt a build mid-shard (writer dropped without
//!   `finish`), reopen, complete, and the resulting cache directory is
//!   **byte-identical** to a one-shot build, `index.json` included;
//! * determinism across tiers — `assemble_sparse_block` over a cold
//!   write-through stack produces bit-identical tensor blocks to the same
//!   assembly over the fully pre-built cache, and a reopened (warm) stack
//!   reports zero origin computes;
//! * the `MemoryTier` front is transparent and its counters move.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use rskd::cache::{
    CacheReader, CacheWriter, MemoryTier, ProbCodec, RangeBlock, TargetSource, WriteThrough,
};
use rskd::coordinator::{
    assemble_sparse_block, assemble_sparse_block_into, AssembleScratch, SparseBlock,
};
use rskd::data::loader::Batch;
use rskd::sampling::random_sampling;
use rskd::sampling::zipf::zipf;
use rskd::spec::{CacheKind, SpecError, Variant};
use rskd::util::rng::Pcg;

const VOCAB: usize = 512;
const CODEC: ProbCodec = ProbCodec::Count { rounds: 50 };

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-tiering-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Position-keyed RS-50 zipf target — the golden-block harness draw, made
/// addressable (seeded per position) so any build order produces it.
fn target_at(pos: u64) -> rskd::cache::SparseTarget {
    let p = zipf(VOCAB, 1.0);
    random_sampling(&p, 50, 1.0, &mut Pcg::new(Pcg::mix_seed(5, pos)))
}

/// Origin serving [0, positions) of `target_at`, counting its compute calls.
struct GoldenOrigin {
    positions: u64,
    computes: AtomicU64,
}

impl GoldenOrigin {
    fn new(positions: u64) -> GoldenOrigin {
        GoldenOrigin { positions, computes: AtomicU64::new(0) }
    }
}

impl TargetSource for GoldenOrigin {
    fn read_range_into(&self, start: u64, len: usize, out: &mut RangeBlock) -> std::io::Result<()> {
        self.computes.fetch_add(1, Ordering::Relaxed);
        out.clear();
        for off in 0..len as u64 {
            match start.checked_add(off) {
                Some(pos) if pos < self.positions => out.push_target(&target_at(pos)),
                _ => out.push_empty(),
            }
        }
        Ok(())
    }

    fn cache_kind(&self) -> Result<CacheKind, SpecError> {
        Ok(CacheKind::Rs { rounds: 50, temp: 1.0 })
    }

    fn positions(&self) -> u64 {
        self.positions
    }
}

/// One-shot golden build over [0, n) with shard span `pps`.
fn build_golden(dir: &Path, n: u64, pps: usize) {
    let w = CacheWriter::create_with_kind(dir, CODEC, pps, 64, Some("rs:rounds=50,temp=1".into()))
        .unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_at(pos)));
    }
    w.finish().unwrap();
}

fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (e.file_name().into_string().unwrap(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    files.sort_by(|a, b| a.0.cmp(&b.0));
    files
}

/// Satellite: interrupt a build mid-shard, reopen, complete — byte-identical
/// to a one-shot build (shards *and* manifest).
#[test]
fn crash_resume_build_is_byte_identical_to_one_shot() {
    let (n, pps) = (90u64, 32usize);
    let golden = tmp_dir("golden");
    build_golden(&golden, n, pps);

    let resumed = tmp_dir("resumed");
    let w =
        CacheWriter::create_with_kind(&resumed, CODEC, pps, 64, Some("rs:rounds=50,temp=1".into()))
            .unwrap();
    // shard 0 completes; shard 1 is mid-flight when the "crash" hits
    for pos in 0..40u64 {
        assert!(w.push(pos, target_at(pos)));
    }
    while w.backlog() > 0 {
        std::thread::yield_now();
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    w.abort(); // drop without finish(): no trailing flush, no manifest
    assert!(!resumed.join("index.json").exists());

    let (w, coverage) =
        CacheWriter::resume(&resumed, CODEC, pps, 64, Some("rs:rounds=50,temp=1".into())).unwrap();
    assert!(coverage.covers(0, 32), "completed shard must be covered");
    assert!(!coverage.contains(32), "mid-flight shard was lost with the crash");
    for pos in 0..n {
        if !coverage.contains(pos) {
            assert!(w.push(pos, target_at(pos)));
        }
    }
    let stats = w.finish().unwrap();
    assert_eq!(stats.positions, n);

    assert_eq!(dir_bytes(&golden), dir_bytes(&resumed), "resumed build must be byte-identical");
    let _ = std::fs::remove_dir_all(&golden);
    let _ = std::fs::remove_dir_all(&resumed);
}

/// Acceptance criterion (engine-free form): assembling training blocks
/// against a cold write-through stack is bit-identical to assembling against
/// a fully pre-built cache, and once the stack has covered the ranges, a
/// reopened stack serves them with zero origin computes.
#[test]
fn cold_stack_assembles_bit_identical_blocks_and_reopens_warm() {
    let (n, pps) = (160u64, 32usize);
    let prebuilt = tmp_dir("prebuilt");
    build_golden(&prebuilt, n, pps);
    let reader = CacheReader::open(&prebuilt).unwrap();

    let (b, s, k_slots) = (4usize, 16usize, 24usize);
    let mut rng = Pcg::new(9);
    let batch = Batch {
        tokens: vec![1i32; b * s],
        labels: (0..b * s).map(|_| rng.below(VOCAB as u64) as i32).collect(),
        // rows: shard-interior, shard-spanning, tail-padding, plain
        offsets: vec![3, 56, 150, 100],
        batch: b,
        seq: s,
    };
    let variant = Variant::Rs { rounds: 50, temp: 1.0 };
    let legacy = assemble_sparse_block(&reader, &batch, VOCAB, k_slots, variant, None);

    let cold_dir = tmp_dir("coldstack");
    {
        let wt = WriteThrough::open(
            GoldenOrigin::new(n),
            &cold_dir,
            CODEC,
            pps,
            Some("rs:rounds=50,temp=1".into()),
        )
        .unwrap()
        .with_align(s as u64);
        let stack = MemoryTier::new(&wt);
        let mut scratch = AssembleScratch::serial();
        let mut blk = SparseBlock::default();
        assemble_sparse_block_into(
            &stack, &batch, VOCAB, k_slots, variant, None, &mut scratch, &mut blk,
        )
        .unwrap();
        assert_eq!(blk.idx, legacy.idx, "cold-stack assembly must match the prebuilt cache");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&blk.val), bits(&legacy.val));
        assert_eq!(bits(&blk.smooth), bits(&legacy.smooth));
        let c = wt.counters();
        assert!(c.misses > 0 && c.backfilled > 0 && c.origin_computes > 0);

        // second epoch over the same rows: memory tier absorbs the reads
        let (hits0, _) = stack.counters();
        assemble_sparse_block_into(
            &stack, &batch, VOCAB, k_slots, variant, None, &mut scratch, &mut blk,
        )
        .unwrap();
        let (hits1, _) = stack.counters();
        assert_eq!(hits1, hits0 + b as u64, "every row must hit the memory tier");
        assert_eq!(
            wt.counters().origin_computes,
            c.origin_computes,
            "the second epoch must not touch the origin"
        );
        wt.checkpoint().unwrap();
    }
    // a new session over the backfilled directory: still bit-identical to
    // the prebuilt cache, and the origin is never consulted
    {
        let origin = GoldenOrigin::new(n);
        let wt = WriteThrough::open(&origin, &cold_dir, CODEC, pps, None).unwrap();
        let mut scratch = AssembleScratch::serial();
        let mut blk = SparseBlock::default();
        assemble_sparse_block_into(
            &wt, &batch, VOCAB, k_slots, variant, None, &mut scratch, &mut blk,
        )
        .unwrap();
        assert_eq!(blk.idx, legacy.idx);
        assert_eq!(origin.computes.load(Ordering::Relaxed), 0, "warm reopen must not recompute");
        assert_eq!(wt.counters().origin_computes, 0);
    }
    let _ = std::fs::remove_dir_all(&prebuilt);
    let _ = std::fs::remove_dir_all(&cold_dir);
}

/// A resumable offline build can *finish* what an on-demand session started:
/// write-through coverage from a partial session is adopted by
/// `CacheWriter::resume`, and the completed directory reads back identical
/// to a one-shot golden build at every position.
#[test]
fn offline_build_resumes_from_write_through_coverage() {
    let (n, pps) = (96u64, 32usize);
    let golden = tmp_dir("golden-handoff");
    build_golden(&golden, n, pps);

    let dir = tmp_dir("handoff");
    {
        // an "on-demand session": only the middle of the stream was touched
        let wt = WriteThrough::open(
            GoldenOrigin::new(n),
            &dir,
            CODEC,
            pps,
            Some("rs:rounds=50,temp=1".into()),
        )
        .unwrap();
        let mut blk = RangeBlock::new();
        wt.read_range_into(20, 50, &mut blk).unwrap(); // covers [20, 70)
        wt.checkpoint().unwrap();
    }
    // the offline build drives the rest to full coverage
    let (w, coverage) =
        CacheWriter::resume(&dir, CODEC, pps, 64, Some("rs:rounds=50,temp=1".into())).unwrap();
    assert!(coverage.covers(20, 70));
    let skipped = coverage.count();
    assert_eq!(skipped, 50);
    for pos in 0..n {
        if !coverage.contains(pos) {
            assert!(w.push(pos, target_at(pos)));
        }
    }
    let stats = w.finish().unwrap();
    assert_eq!(stats.positions, n);

    // every position decodes identically to the one-shot golden build
    let a = CacheReader::open(&golden).unwrap();
    let b = CacheReader::open(&dir).unwrap();
    let (mut ba, mut bb) = (RangeBlock::new(), RangeBlock::new());
    a.read_range_into(0, n as usize, &mut ba).unwrap();
    b.read_range_into(0, n as usize, &mut bb).unwrap();
    assert_eq!(ba, bb, "handoff build must decode identical to one-shot");
    let _ = std::fs::remove_dir_all(&golden);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: `Coverage` round-trips through the `index.json` `covered`
/// field. A checkpointed partial session's exact runs — including a run
/// produced by merging two adjacent reads — survive the manifest and are
/// re-adopted by a reopened stack; full coverage round-trips as the single
/// `[0, n)` run with every `covered` entry elided (fully-covered shards
/// carry no range list).
#[test]
fn coverage_round_trips_through_index_json_covered_field() {
    use rskd::cache::CacheManifest;

    let (n, pps) = (128u64, 32usize);
    let dir = tmp_dir("covjson");
    let partial = {
        let wt = WriteThrough::open(
            GoldenOrigin::new(n),
            &dir,
            CODEC,
            pps,
            Some("rs:rounds=50,temp=1".into()),
        )
        .unwrap();
        let mut blk = RangeBlock::new();
        wt.read_range_into(10, 20, &mut blk).unwrap(); // [10, 30)
        wt.read_range_into(30, 10, &mut blk).unwrap(); // adjacent: merges to [10, 40)
        wt.read_range_into(90, 12, &mut blk).unwrap(); // [90, 102), spans shards 2 and 3
        wt.read_range_into(5, 0, &mut blk).unwrap(); // zero-length: must not mark anything
        wt.checkpoint().unwrap();
        wt.coverage()
    };
    assert_eq!(partial.ranges(), &[(10, 40), (90, 102)]);

    // the manifest records exactly those runs, clipped per shard
    let manifest = CacheManifest::load(&dir).unwrap();
    let mut persisted = rskd::cache::Coverage::new();
    for s in &manifest.shards {
        match &s.covered {
            Some(runs) => {
                for &(lo, hi) in runs {
                    assert!(s.start <= lo && hi <= s.start + s.count, "covered run outside shard");
                    persisted.insert(lo, hi);
                }
            }
            None => persisted.insert(s.start, s.start + s.count),
        }
    }
    assert_eq!(persisted, partial, "index.json must carry the exact coverage");

    // a reopened stack adopts the persisted coverage and serves those runs
    // without recomputing them
    {
        let origin = GoldenOrigin::new(n);
        let wt = WriteThrough::open(&origin, &dir, CODEC, pps, None).unwrap();
        assert_eq!(wt.coverage(), partial, "reopen must adopt the persisted runs");
        let mut blk = RangeBlock::new();
        wt.read_range_into(10, 30, &mut blk).unwrap();
        wt.read_range_into(90, 12, &mut blk).unwrap();
        assert_eq!(origin.computes.load(Ordering::Relaxed), 0, "covered runs must not recompute");

        // drive to full coverage and checkpoint again
        wt.read_range_into(0, n as usize, &mut blk).unwrap();
        wt.checkpoint().unwrap();
        assert_eq!(wt.coverage().ranges(), &[(0, n)], "full keyspace must be one run");
    }
    let manifest = CacheManifest::load(&dir).unwrap();
    assert!(
        manifest.shards.iter().all(|s| s.covered.is_none()),
        "fully-covered shards must elide the `covered` list"
    );
    assert_eq!(manifest.shards.iter().map(|s| s.count).sum::<u64>(), n);
    let _ = std::fs::remove_dir_all(&dir);
}
