//! Integration tests for the concurrent serving surface: ≥4 client threads
//! issuing overlapping ranges against one server, byte-identical results vs.
//! a direct `CacheReader`, in-flight fetch coalescing asserted via `Stats`
//! counters, admission control under a saturated worker pool, and typed
//! error frames — over both transports (loopback TCP and Unix socket).

use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use rskd::cache::{
    CacheReader, CacheWriter, DynSource, ProbCodec, SparseTarget, TargetSource, WriteThrough,
};
use rskd::sampling::SyntheticZipfSource;
use rskd::serve::{
    Endpoint, ErrCode, Request, Response, ServeClient, ServeConfig, ServedReader, Server,
};
use rskd::spec::{CacheKind, DistillSpec};

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn target_for(pos: u64) -> SparseTarget {
    SparseTarget {
        ids: vec![pos as u32 % 97, 200 + (pos as u32 % 7), 400],
        probs: vec![20.0 / 50.0, 10.0 / 50.0, 5.0 / 50.0],
    }
}

/// `n` positions in shards of 16, tagged as an RS-50 cache.
fn build_cache(dir: &std::path::Path, n: u64) {
    let w = CacheWriter::create_with_kind(
        dir,
        ProbCodec::Count { rounds: 50 },
        16,
        32,
        Some("rs:rounds=50,temp=1".into()),
    )
    .unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();
}

fn tcp0() -> Endpoint {
    Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0)))
}

#[test]
fn four_clients_overlapping_ranges_byte_identical() {
    let dir = tdir("ident");
    build_cache(&dir, 256); // 16 shards
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server = Server::start(Arc::clone(&reader), tcp0(), ServeConfig::default()).unwrap();
    let endpoint = server.endpoint().clone();
    let direct = CacheReader::open(&dir).unwrap();

    let barrier = Barrier::new(4);
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let endpoint = &endpoint;
            let direct = &direct;
            let barrier = &barrier;
            s.spawn(move || {
                let mut client = ServeClient::connect(endpoint).unwrap();
                barrier.wait();
                // overlapping strided ranges, including ones that span shard
                // boundaries and run past the end (missing -> empty targets)
                for i in 0..32u64 {
                    let start = (c * 8 + i * 5) % 250;
                    let len = 40;
                    let served = client.get_range(start, len).unwrap();
                    let local = direct.get_range(start, len);
                    assert_eq!(served, local, "range [{start}, +{len}) must be byte-identical");
                }
            });
        }
    });
    let snap = server.stats_snapshot();
    assert_eq!(snap.requests, 4 * 32);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rejected, 0);
    assert!(snap.p50_us().is_some() && snap.p99_us().is_some());
    assert!(snap.p50_us() <= snap.p99_us());
    // hot-shard counters saw traffic
    assert!(!snap.hot_shards(5).is_empty());
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance-criterion test: duplicate/overlapping in-flight range
/// requests are served by a single underlying shard read. A 50 ms simulated
/// disk keeps every first-touch decode in flight while all four clients
/// race; the `Stats` counters then prove no shard was read twice
/// (`shard_loads == shards on disk`, despite 4x overlapping coverage) and
/// that at least one racing load piggybacked (`coalesced > 0`).
#[test]
fn coalescing_collapses_duplicate_in_flight_fetches() {
    let dir = tdir("coalesce");
    build_cache(&dir, 128); // 8 shards of 16
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    reader.set_load_delay(Duration::from_millis(50));
    // 4 workers so the 4 clients are genuinely concurrent in the pool, and
    // ranges that *start* in different shards (distinct workers) but overlap
    // on interior shards — the cross-worker duplicate-fetch case
    let cfg = ServeConfig { workers: 4, ..Default::default() };
    let server = Server::start(Arc::clone(&reader), tcp0(), cfg).unwrap();
    let endpoint = server.endpoint().clone();

    let barrier = Barrier::new(4);
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let endpoint = &endpoint;
            let barrier = &barrier;
            s.spawn(move || {
                let mut client = ServeClient::connect(endpoint).unwrap();
                barrier.wait();
                // client c covers [16c, 16c + 80): starts in shard c, spans
                // 5 shards, so consecutive clients overlap on 4 of them
                let served = client.get_range(c * 16, 80).unwrap();
                assert_eq!(served.len(), 80);
                assert_eq!(served[0], target_for(c * 16));
            });
        }
    });
    let snap = server.stats_snapshot();
    assert_eq!(snap.requests, 4);
    // every one of the 8 shards was decoded exactly once, even though the
    // four ranges covered shards 0..8 with 4x overlap in flight
    assert_eq!(snap.shard_loads, 8, "duplicate in-flight fetches must collapse");
    assert!(
        snap.coalesced > 0,
        "with a 50 ms simulated disk, at least one racing load must piggyback"
    );
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn admission_control_sheds_load_with_typed_overload() {
    let dir = tdir("admission");
    build_cache(&dir, 64);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    reader.set_load_delay(Duration::from_millis(100));
    // one worker, one queue slot: >2 concurrent requests must be shed
    let cfg = ServeConfig { workers: 1, queue_cap: 1, ..Default::default() };
    let server = Server::start(Arc::clone(&reader), tcp0(), cfg).unwrap();
    let endpoint = server.endpoint().clone();

    let barrier = Barrier::new(6);
    let overloaded = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..6u64 {
            let endpoint = &endpoint;
            let barrier = &barrier;
            let overloaded = &overloaded;
            s.spawn(move || {
                let mut client = ServeClient::connect(endpoint).unwrap();
                client.overload.retries = 0; // surface the first shed
                barrier.wait();
                // all clients hammer the same cold shard
                match client.get_range(c % 4, 8) {
                    Ok(t) => assert_eq!(t.len(), 8),
                    Err(e) => {
                        assert_eq!(e.kind(), std::io::ErrorKind::WouldBlock, "{e}");
                        overloaded.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let shed = overloaded.load(std::sync::atomic::Ordering::Relaxed);
    assert!(shed >= 1, "6 racing clients through a 1-slot queue must shed load");
    let snap = server.stats_snapshot();
    assert_eq!(snap.rejected, shed);
    // a shed client retries successfully once the queue drains
    let mut client = ServeClient::connect(&endpoint).unwrap();
    assert_eq!(client.get_range(0, 8).unwrap().len(), 8);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unix_socket_transport_and_served_reader_kind_check() {
    let dir = tdir("unix");
    build_cache(&dir, 64);
    let sock = std::env::temp_dir().join(format!("rskd-serve-{}.sock", std::process::id()));
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server =
        Server::start(Arc::clone(&reader), Endpoint::Unix(sock.clone()), ServeConfig::default())
            .unwrap();

    let served = ServedReader::connect(server.endpoint()).unwrap();
    // advertised manifest matches the directory
    assert_eq!(served.manifest().positions, 64);
    assert_eq!(served.manifest().shard_count, 4);
    assert_eq!(served.manifest().kind.as_deref(), Some("rs:rounds=50,temp=1"));
    assert_eq!(served.cache_kind().unwrap(), CacheKind::Rs { rounds: 50, temp: 1.0 });
    // the spec-compatibility contract works against the advertised kind:
    // its native spec serves, a Top-K spec is refused with a typed error
    assert!(DistillSpec::rs(50).check_cache(served.cache_kind().unwrap()).is_ok());
    assert!(DistillSpec::topk(12).check_cache(served.cache_kind().unwrap()).is_err());
    // and the TargetSource surface reads through the wire
    let ts = served.try_get_range(10, 8).unwrap();
    let direct = CacheReader::open(&dir).unwrap();
    assert_eq!(ts, direct.get_range(10, 8));
    assert_eq!(TargetSource::positions(&served), 64);

    drop(server);
    assert!(!sock.exists(), "shutdown must unlink the unix socket file");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn typed_error_frames_for_bad_requests() {
    let dir = tdir("errors");
    build_cache(&dir, 32);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let cfg = ServeConfig { max_range: 64, ..Default::default() };
    let server = Server::start(Arc::clone(&reader), tcp0(), cfg).unwrap();

    // oversized range -> RangeTooLarge (client maps it to InvalidInput)
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let err = client.get_range(0, 65).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput);
    assert!(err.to_string().contains("RangeTooLarge"), "{err}");

    // raw protocol: an unknown opcode answers a BadRequest error frame and
    // the connection survives for the next (valid) request
    use rskd::serve::protocol::{read_frame, write_frame};
    let Endpoint::Tcp(addr) = server.endpoint() else { panic!("tcp expected") };
    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    write_frame(&mut raw, &[rskd::serve::PROTOCOL_VERSION, 0x7F]).unwrap();
    let frame = read_frame(&mut raw).unwrap().unwrap();
    let Response::Error { code, .. } = Response::decode(&frame).unwrap() else {
        panic!("expected an error frame")
    };
    assert_eq!(code, ErrCode::BadRequest);
    // wrong protocol version -> BadVersion
    write_frame(&mut raw, &[99, 0x01]).unwrap();
    let frame = read_frame(&mut raw).unwrap().unwrap();
    let Response::Error { code, .. } = Response::decode(&frame).unwrap() else {
        panic!("expected an error frame")
    };
    assert_eq!(code, ErrCode::BadVersion);
    // the same connection still serves a well-formed request
    write_frame(&mut raw, &Request::Ping.encode()).unwrap();
    let frame = read_frame(&mut raw).unwrap().unwrap();
    assert_eq!(Response::decode(&frame).unwrap(), Response::Pong);

    let snap = server.stats_snapshot();
    assert!(snap.errors >= 3);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The serve-layer miss path: a server over a *cold* write-through stack
/// answers `GetRange` by computing via the origin, backfilling the shard,
/// and serving — then a repeat of the same ranges is served entirely from
/// the disk tier (`tier.misses` and `tier.origin_computes` stop moving),
/// byte-identical, and the directory reopens warm across servers.
#[test]
fn cold_backfill_server_warms_up_and_serves_from_disk() {
    let dir = tdir("backfill");
    let stack = |computed_dir: &std::path::Path| -> Arc<WriteThrough<DynSource>> {
        let origin: DynSource = Box::new(SyntheticZipfSource::new(128, 256, 50, 7));
        Arc::new(
            WriteThrough::open(
                origin,
                computed_dir,
                ProbCodec::Count { rounds: 50 },
                16,
                Some("rs:rounds=50,temp=1".into()),
            )
            .unwrap(),
        )
    };
    let first_pass: Vec<Vec<SparseTarget>>;
    {
        let server = Server::start(stack(&dir), tcp0(), ServeConfig::default()).unwrap();
        let mut client = ServeClient::connect(server.endpoint()).unwrap();
        // the advertised manifest lets spec checks run against a cold cache
        let served = ServedReader::connect(server.endpoint()).unwrap();
        assert_eq!(served.cache_kind().unwrap(), CacheKind::Rs { rounds: 50, temp: 1.0 });
        assert_eq!(served.manifest().positions, 256);

        let ranges = [(0u64, 40usize), (100, 40), (30, 90), (240, 32)];
        first_pass = ranges.iter().map(|&(s, l)| client.get_range(s, l).unwrap()).collect();
        let cold = server.stats_snapshot();
        assert!(cold.tier.misses > 0, "a cold server must miss");
        assert!(cold.tier.backfilled > 0);
        assert!(cold.tier.origin_computes > 0);

        // repeat: zero new misses / computes, identical bytes
        let warm_pass: Vec<Vec<SparseTarget>> =
            ranges.iter().map(|&(s, l)| client.get_range(s, l).unwrap()).collect();
        assert_eq!(warm_pass, first_pass, "warm answers must be byte-identical");
        let warm = server.stats_snapshot();
        assert_eq!(warm.tier.misses, cold.tier.misses, "second pass must not miss");
        assert_eq!(warm.tier.origin_computes, cold.tier.origin_computes);
        assert_eq!(warm.tier.hits, cold.tier.hits + ranges.len() as u64);
        drop(server);
    }
    // a brand-new server over the same directory reopens with the coverage
    // intact: same bytes, still zero origin computes
    {
        let reopened = stack(&dir);
        let server = Server::start(Arc::clone(&reopened), tcp0(), ServeConfig::default()).unwrap();
        let mut client = ServeClient::connect(server.endpoint()).unwrap();
        let again: Vec<Vec<SparseTarget>> = [(0u64, 40usize), (100, 40), (30, 90), (240, 32)]
            .iter()
            .map(|&(s, l)| client.get_range(s, l).unwrap())
            .collect();
        assert_eq!(again, first_pass, "a reopened cache must serve the same bytes");
        assert_eq!(server.stats_snapshot().tier.origin_computes, 0);
        drop(server);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
