//! Integration tests over the full three-layer stack: corpus -> BPE ->
//! packing -> teacher pre-training (PJRT) -> L1 sampler cache -> student
//! training -> evaluation. Requires `make artifacts` (skips otherwise).

use std::path::PathBuf;

use rskd::cache::{CacheReader, ProbCodec, SparseTarget};
use rskd::coordinator::{Pipeline, PipelineConfig};
use rskd::evalsuite::tasks::{build_cloze_tasks, zero_shot_score};
use rskd::model::ModelState;
use rskd::runtime::{Engine, HostTensor};
use rskd::spec::{CacheKind, DistillSpec, SpecError};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/small"));
    p.join("manifest.json").exists().then_some(p)
}

fn micro_cfg(dir: PathBuf) -> PipelineConfig {
    PipelineConfig {
        artifact_dir: dir,
        target_tokens: 50_000,
        teacher_steps: 30,
        student_steps: 14,
        eval_batches: 2,
        work_dir: PathBuf::from("target/test-pipeline"),
        ..Default::default()
    }
}

/// One shared end-to-end pass exercising every stage (single test to share
/// the PJRT compile cost).
#[test]
fn full_stack_end_to_end() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts/small not built");
        return;
    };
    let mut pipe = Pipeline::prepare(micro_cfg(dir)).unwrap();
    assert!(pipe.teacher_losses.iter().all(|l| l.is_finite()));
    assert!(
        pipe.teacher_losses.last().unwrap() < pipe.teacher_losses.first().unwrap(),
        "teacher CE did not decrease: {:?}",
        pipe.teacher_losses
    );

    // --- cache build via the L1 Pallas sampler graph (registry-resolved) ---
    let rs_spec = DistillSpec::rs(50);
    let rs = pipe.ensure_cache(&rs_spec).unwrap().unwrap();
    assert!(rs.stats.cache.positions > 1000);
    assert!(rs.stats.avg_unique_tokens > 1.0 && rs.stats.avg_unique_tokens <= 50.0);
    // the manifest records the kind the spec derived
    assert_eq!(rs.reader.cache_kind().unwrap(), CacheKind::Rs { rounds: 50, temp: 1.0 });
    // count codec: decoded weights are multiples of 1/50 and sum to 1
    let t = rs.reader.get(0).unwrap();
    let mass: f32 = t.probs.iter().sum();
    assert!((mass - 1.0).abs() < 1e-4, "mass {mass}");
    for &p in &t.probs {
        let x = p * 50.0;
        assert!((x - x.round()).abs() < 1e-4);
    }
    // memoization: a second spec with the same plan reuses the build
    let rs_again = pipe.ensure_cache(&rs_spec.with_alpha(0.1)).unwrap().unwrap();
    assert!(std::sync::Arc::ptr_eq(&rs.reader, &rs_again.reader));

    let tk_spec = DistillSpec::topk(12);
    let tk = pipe.ensure_cache(&tk_spec).unwrap().unwrap();
    assert_eq!(tk.stats.cache.positions, rs.stats.cache.positions);
    assert_eq!(tk.reader.cache_kind().unwrap(), CacheKind::TopK);
    let t = tk.reader.get(10).unwrap();
    // ratio codec decodes sorted descending
    for w in t.probs.windows(2) {
        assert!(w[0] >= w[1] - 1e-6);
    }

    // storage: 24-bit slots -> RS cache stores ~3 bytes per kept logit
    let bytes_per_slot = rs.stats.cache.bytes as f64 / rs.stats.cache.slots as f64;
    assert!(bytes_per_slot < 3.2, "bytes/slot {bytes_per_slot}");

    // --- typed incompatibility: Top-K spec over the RS cache must fail
    //     *before* training (this used to silently truncate id-sorted draws)
    let err = pipe.run_student(&tk_spec, Some(rs.reader.as_ref()), 5).unwrap_err();
    let spec_err = err.downcast_ref::<SpecError>().expect("typed SpecError");
    assert!(matches!(spec_err, SpecError::Incompatible { .. }), "{spec_err:?}");
    // ... and so must an RS spec over the Top-K cache, or a missing cache
    let err = pipe.run_student(&rs_spec, Some(tk.reader.as_ref()), 5).unwrap_err();
    assert!(matches!(err.downcast_ref::<SpecError>(), Some(SpecError::Incompatible { .. })));
    let err = pipe.run_student(&rs_spec, None, 5).unwrap_err();
    assert!(matches!(err.downcast_ref::<SpecError>(), Some(SpecError::MissingCache { .. })));
    // ... and a spec wider than the AOT slot budget is rejected up front
    let k_slots = pipe.engine.manifest().k_slots;
    let wide = DistillSpec::topk(k_slots + 1);
    let err = pipe.run_student(&wide, Some(tk.reader.as_ref()), 5).unwrap_err();
    assert!(matches!(err.downcast_ref::<SpecError>(), Some(SpecError::SlotOverflow { .. })));

    // --- students across methods (run_spec resolves caches itself) ---
    let (_, tr_ce, ev_ce) = pipe.run_spec(&DistillSpec::ce(), 5).unwrap();
    assert!(!tr_ce.diverged);
    assert!(ev_ce.lm_loss.is_finite() && ev_ce.lm_loss > 0.0);

    let (student_rs, tr_rs, ev_rs) = pipe.run_spec(&rs_spec, 5).unwrap();
    assert!(!tr_rs.diverged);
    assert!(tr_rs.losses.last().unwrap() < tr_rs.losses.first().unwrap());
    assert!(ev_rs.spec_accept_pct > 10.0 && ev_rs.spec_accept_pct <= 100.0);

    let (_, tr_tk, _) = pipe.run_spec(&tk_spec, 5).unwrap();
    assert!(!tr_tk.diverged);

    let (_, tr_fk, ev_fk) = pipe.run_spec(&DistillSpec::full_kd(), 5).unwrap();
    assert!(!tr_fk.diverged);
    assert!(ev_fk.lm_loss.is_finite());

    // --- evalsuite on the trained student ---
    let eval_loader = pipe.eval_loader();
    let seqs: Vec<_> = eval_loader.iter_eval().flat_map(|b| {
        (0..b.batch).map(move |r| rskd::data::packing::Sequence {
            tokens: b.tokens[r * b.seq..(r + 1) * b.seq].iter().map(|&t| t as u32).collect(),
            labels: b.labels[r * b.seq..(r + 1) * b.seq].iter().map(|&t| t as u32).collect(),
            stream_offset: b.offsets[r],
        }).collect::<Vec<_>>()
    }).collect();
    let tasks = build_cloze_tasks(&seqs, 8, 16, 4, 3);
    if !tasks.is_empty() {
        let score = zero_shot_score(&pipe.engine, &student_rs, &tasks).unwrap();
        assert!((0.0..=100.0).contains(&score), "{score}");
    }
}

/// The sparse graph generalizes FullKD: feeding the full distribution as a
/// "sparse" target must match the dense graph's loss (cross-layer check).
#[test]
fn sparse_graph_generalizes_dense() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let m = engine.manifest();
    let (b, s, v, k) = (m.batch, m.seq, m.vocab, m.k_slots);
    assert!(k <= v);

    let student = ModelState::init(&engine, "student", 1).unwrap();
    let teacher = ModelState::init(&engine, "teacher", 2).unwrap();
    let toks = HostTensor::i32(vec![5; b * s], &[b, s]);
    let labels = HostTensor::i32(vec![6; b * s], &[b, s]);
    let probs = engine
        .call("fwd_teacher", &[teacher.params_tensor(), toks.clone()])
        .unwrap()
        .remove(0);

    // top-k of the dense distribution as sparse target, k = k_slots
    let mut outs = engine.call("sample_topk", &[probs.clone()]).unwrap();
    let vals = outs.remove(1);
    let ids = outs.remove(0);

    let [p, mm, vv, st] = student.opt_inputs();
    let sparse = engine
        .call(
            "train_sparse_student",
            &[
                p, mm, vv, st,
                HostTensor::scalar_f32(0.0), // lr 0: loss probe only
                toks.clone(),
                labels.clone(),
                ids,
                vals,
                HostTensor::scalar_f32(0.0),
                HostTensor::f32(vec![0.0; b * s], &[b, s]),
                HostTensor::scalar_f32(0.0),
                HostTensor::f32(vec![1.0; b * s], &[b, s]),
            ],
        )
        .unwrap();
    let kd_sparse = sparse[5].scalar().unwrap();

    let [p, mm, vv, st] = student.opt_inputs();
    let dense = engine
        .call(
            "train_dense_student",
            &[p, mm, vv, st, HostTensor::scalar_f32(0.0), toks, labels, probs,
              HostTensor::scalar_f32(0.0)],
        )
        .unwrap();
    let kd_dense = dense[5].scalar().unwrap();

    // top-64 of a 512-vocab head covers most mass; losses should be close,
    // with the sparse one *smaller* (it omits tail KLD terms, which are
    // positive when the student is near-uniform) — tight equality is checked
    // in python where the full distribution fits in k_slots.
    assert!(kd_sparse <= kd_dense + 0.05, "sparse {kd_sparse} dense {kd_dense}");
    assert!(kd_sparse > 0.1 * kd_dense, "sparse {kd_sparse} dense {kd_dense}");
}

/// Cache addressing is positional: reading a range across shard boundaries
/// returns the same targets as pointwise gets. Also pins the manifest kind
/// round-trip the spec-layer compatibility checks rely on.
#[test]
fn cache_range_consistency() {
    let dir = std::env::temp_dir().join(format!("rskd-it-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let w = rskd::cache::CacheWriter::create_with_kind(
        &dir,
        ProbCodec::Ratio,
        7,
        4,
        Some(CacheKind::TopK.to_string()),
    )
    .unwrap();
    for pos in 0..40u64 {
        assert!(w.push(pos, SparseTarget { ids: vec![pos as u32, 500], probs: vec![0.5, 0.25] }));
    }
    w.finish().unwrap();
    let r = CacheReader::open(&dir).unwrap();
    let kind = r.cache_kind().unwrap();
    assert_eq!(kind, CacheKind::TopK);
    // the kind gates specs: a Top-K family spec passes, an RS spec does not
    assert!(DistillSpec::topk(5).check_cache(kind).is_ok());
    assert!(DistillSpec::rs(5).check_cache(kind).is_err());
    let range = r.get_range(3, 20);
    for (i, t) in range.iter().enumerate() {
        assert_eq!(t.ids, r.get(3 + i as u64).unwrap().ids);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tiered-sources acceptance criterion, end to end: a student trained
/// against a **cold** write-through stack (teacher-computed misses,
/// quantize-on-the-way-in backfill) produces bit-identical losses to one
/// trained against a fully pre-built cache of the same spec/seed — and once
/// the stack has seen a full pass, a repeat run computes nothing
/// (`teacher_computes == 0`; everything served from the disk tier).
#[test]
fn cold_on_demand_stack_matches_prebuilt_cache() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts/small not built");
        return;
    };
    let mut cfg = micro_cfg(dir);
    cfg.work_dir = PathBuf::from("target/test-ondemand");
    let mut pipe = Pipeline::prepare(cfg).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

    // rounds=25 truncates the AOT sampler's draw; distinct from the rs(50)
    // registry entries other tests build
    let spec = DistillSpec::rs(25);
    let tag = spec.cache_plan().unwrap().dir_tag();
    // make the stack genuinely cold across test re-runs
    let _ = std::fs::remove_dir_all(pipe.cache_dir(&tag));

    let (_s1, tr_cold, ev_cold, tiers_cold) = pipe.run_spec_on_demand(&spec, 5).unwrap();
    assert!(!tr_cold.diverged);
    assert!(tiers_cold.origin_computes > 0, "a cold stack must compute via the teacher");
    assert!(tiers_cold.backfilled > 0);
    assert!(ev_cold.lm_loss.is_finite());

    // the offline path resumes the partially-backfilled directory to full
    // coverage, then trains with the default (prefetched) loop
    let (_s2, tr_pre, _ev_pre) = pipe.run_spec(&spec, 5).unwrap();
    assert_eq!(
        bits(&tr_cold.losses),
        bits(&tr_pre.losses),
        "cold write-through stack must train bit-identically to the prebuilt cache"
    );
    assert_eq!(bits(&tr_cold.kd_losses), bits(&tr_pre.kd_losses));

    // warm repeat: the directory is fully covered now — zero teacher computes
    let (_s3, tr_warm, _ev_warm, tiers_warm) = pipe.run_spec_on_demand(&spec, 5).unwrap();
    assert_eq!(tiers_warm.origin_computes, 0, "warm stack must not touch the teacher");
    assert_eq!(tiers_warm.backfilled, 0);
    assert_eq!(bits(&tr_warm.losses), bits(&tr_cold.losses));
}
