//! Integration tests for the observability layer (`rskd::obs`,
//! docs/OBSERVABILITY.md): histogram quantile edge cases under the
//! ≤2x-overestimate contract, cross-registry snapshot merging, and the
//! end-to-end trace decomposition over a live server — a traced
//! `read_range_into` must leave a Root → Segment → Server span chain in the
//! ring whose echoed queue/decode/origin phases agree exactly across the
//! wire.

use std::path::PathBuf;
use std::sync::Arc;

use rskd::cache::{CacheReader, CacheWriter, ProbCodec, RangeBlock, SparseTarget};
use rskd::obs::{
    self, hist_quantile_us, obs_bucket_upper_us, parse_prometheus, Registry, Snapshot,
    OBS_HIST_BUCKETS,
};
use rskd::serve::{Endpoint, ServeClient, ServeConfig, Server};
use rskd::util::rng::Pcg;

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-obs-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `n` positions in shards of 16, tagged as an RS-50 cache.
fn build_cache(dir: &std::path::Path, n: u64) {
    let w = CacheWriter::create_with_kind(
        dir,
        ProbCodec::Count { rounds: 50 },
        16,
        32,
        Some("rs:rounds=50,temp=1".into()),
    )
    .unwrap();
    for pos in 0..n {
        let t = SparseTarget {
            ids: vec![pos as u32 % 97, 200 + (pos as u32 % 7), 400],
            probs: vec![20.0 / 50.0, 10.0 / 50.0, 5.0 / 50.0],
        };
        assert!(w.push(pos, t));
    }
    w.finish().unwrap();
}

// ---------------------------------------------------------------------------
// histogram quantile edge cases
// ---------------------------------------------------------------------------

#[test]
fn empty_histogram_has_no_quantiles() {
    assert_eq!(hist_quantile_us(&[0u64; OBS_HIST_BUCKETS], 0.5), None);
    assert_eq!(hist_quantile_us(&[], 0.99), None);
    let r = Registry::new();
    r.hist("rskd_empty_us", &[]);
    assert_eq!(r.snapshot().quantile_us("rskd_empty_us", 0.5), None);
    assert_eq!(r.snapshot().quantile_us("rskd_never_registered", 0.5), None);
}

#[test]
fn single_bucket_saturation_pins_every_quantile_to_its_edge() {
    // every sample in [128, 256) µs: all quantiles report the upper edge
    let mut buckets = vec![0u64; OBS_HIST_BUCKETS];
    buckets[7] = 1_000_000;
    for q in [0.0, 0.5, 0.99, 1.0] {
        assert_eq!(hist_quantile_us(&buckets, q), Some(256), "q={q}");
    }
    // the overflow bucket saturates at its capped edge, never past it
    let mut top = vec![0u64; OBS_HIST_BUCKETS];
    top[OBS_HIST_BUCKETS - 1] = 5;
    let edge = obs_bucket_upper_us(OBS_HIST_BUCKETS - 1);
    assert_eq!(hist_quantile_us(&top, 0.5), Some(edge));
    assert_eq!(hist_quantile_us(&top, 1.0), Some(edge));
}

#[test]
fn quantiles_are_monotone_in_q() {
    let mut rng = Pcg::new(42);
    for round in 0..50u64 {
        let mut buckets = vec![0u64; OBS_HIST_BUCKETS];
        for b in buckets.iter_mut() {
            *b = rng.below(5);
        }
        if buckets.iter().sum::<u64>() == 0 {
            continue;
        }
        let vals: Vec<u64> = [0.0, 0.25, 0.5, 0.9, 0.99, 1.0]
            .iter()
            .map(|&q| hist_quantile_us(&buckets, q).unwrap())
            .collect();
        for w in vals.windows(2) {
            assert!(w[0] <= w[1], "round {round}: non-monotone {vals:?} over {buckets:?}");
        }
    }
}

#[test]
fn reported_quantiles_overestimate_by_at_most_2x() {
    let r = Registry::new();
    let h = r.hist("rskd_contract_us", &[]);
    let mut rng = Pcg::new(7);
    let mut samples: Vec<u64> = (0..500).map(|_| 1 + rng.below(1_000_000)).collect();
    for &s in &samples {
        h.record_us(s);
    }
    samples.sort_unstable();
    let snap = r.snapshot();
    for q in [0.5, 0.9, 0.99] {
        let reported = snap.quantile_us("rskd_contract_us", q).unwrap();
        let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
        let exact = samples[rank - 1];
        assert!(reported >= exact, "p{q}: reported {reported} under-promises exact {exact}");
        assert!(reported <= exact * 2, "p{q}: reported {reported} > 2x exact {exact}");
    }
}

#[test]
fn merged_snapshots_from_two_registries_quantile_over_combined_buckets() {
    // a fast member and a slow member: the merged p99 must surface the slow
    // tail neither registry reports alone
    let a = Registry::new();
    let b = Registry::new();
    let ha = a.hist("rskd_merge_us", &[]);
    let hb = b.hist("rskd_merge_us", &[]);
    for _ in 0..90 {
        ha.record_us(4); // bucket 2, upper edge 8 µs
    }
    for _ in 0..10 {
        hb.record_us(5000); // bucket 12, upper edge 8192 µs
    }
    let m = a.snapshot().merge(&b.snapshot());
    assert_eq!(m.sum("rskd_merge_us"), 100);
    assert_eq!(m.quantile_us("rskd_merge_us", 0.5), Some(8));
    assert_eq!(m.quantile_us("rskd_merge_us", 0.99), Some(8192));
    assert_eq!(
        a.snapshot().quantile_us("rskd_merge_us", 0.99),
        Some(8),
        "the fast member alone cannot see the tail"
    );
}

// ---------------------------------------------------------------------------
// end-to-end: traced serve roundtrip + exposition wire frames
// ---------------------------------------------------------------------------

#[test]
fn traced_serve_roundtrip_decomposes_end_to_end() {
    let dir = tdir("e2e");
    build_cache(&dir, 200);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server =
        Server::start(reader, Endpoint::Unix(dir.join("s.sock")), ServeConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();

    let trace = obs::mint_trace();
    {
        let root =
            obs::SpanScope::begin(obs::spans(), obs::SpanKind::Root, trace, 0, u32::MAX, 10, 64);
        let mut block = RangeBlock::new();
        client.read_range_into(10, 64, &mut block).unwrap();
        assert_eq!(block.len(), 64);
        root.finish();
    }

    // server worker + client share this process's ring: the whole chain is
    // already recorded by the time the response has been decoded
    let spans = obs::spans().drain_ordered();
    let mine: Vec<_> = spans.iter().filter(|s| s.trace == trace).collect();
    let root = mine.iter().find(|s| s.kind == obs::SpanKind::Root).expect("root span");
    let seg = mine.iter().find(|s| s.kind == obs::SpanKind::Segment).expect("segment span");
    let srv = mine.iter().find(|s| s.kind == obs::SpanKind::Server).expect("server span");

    // the segment's phases sum to its measured rtt, inside its own total,
    // inside the parent's total
    let seg_phases: u64 = seg.phases.iter().sum();
    assert!(seg_phases > 0, "{seg:?}");
    assert!(seg_phases <= seg.total_ns, "phases exceed the span: {seg:?}");
    assert!(seg.total_ns <= root.total_ns, "child escapes its parent: root {root:?} seg {seg:?}");

    // the server-side echo is byte-exact: what the segment attributes as
    // queue/decode/origin is precisely what the server span recorded
    assert_eq!(seg.phases[0], srv.phases[0], "queue echo drifted: {seg:?} vs {srv:?}");
    assert_eq!(seg.phases[1], srv.phases[1], "decode echo drifted: {seg:?} vs {srv:?}");
    assert_eq!(seg.phases[2], srv.phases[2], "origin echo drifted: {seg:?} vs {srv:?}");
    assert_eq!(srv.phases[3], 0, "a server span has no network phase: {srv:?}");
    assert_eq!((srv.start, srv.len), (10, 64), "{srv:?}");

    // JSONL exposition of the chain stays one object per line
    for s in &mine {
        let line = s.to_jsonl();
        assert!(line.starts_with('{') && line.ends_with('}') && !line.contains('\n'), "{line}");
        assert!(line.contains(&format!("{:016x}", trace)), "{line}");
    }

    // untraced requests record nothing: every span in the (process-shared)
    // ring carries a real trace id — asserted this way because parallel
    // tests may be recording their own traced spans concurrently
    let mut block = RangeBlock::new();
    client.read_range_into(0, 16, &mut block).unwrap();
    assert_eq!(block.len(), 16);
    assert!(
        obs::spans().drain_ordered().iter().all(|s| s.trace != 0),
        "an untraced request must never reach the ring"
    );

    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn metrics_and_trace_frames_over_the_wire() {
    let dir = tdir("wire");
    build_cache(&dir, 96);
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server =
        Server::start(reader, Endpoint::Unix(dir.join("s.sock")), ServeConfig::default())
            .unwrap();
    let endpoint = server.endpoint().to_string();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    for start in [0u64, 16, 32] {
        assert_eq!(client.get_range(start, 8).unwrap().len(), 8);
    }

    // GetMetrics: parses, carries this endpoint's labeled series, and
    // reconstructs into a snapshot that sums/quantiles like a local one
    let text = client.metrics().unwrap();
    let parsed = parse_prometheus(&text).unwrap();
    let served = parsed
        .iter()
        .find(|(n, ls, _)| {
            n == "rskd_serve_requests_total"
                && ls.iter().any(|(k, v)| k == "endpoint" && *v == endpoint)
        })
        .expect("requests_total for this endpoint");
    assert!(served.2 >= 3.0, "{served:?}");
    let snap = Snapshot::from_prometheus(&text).unwrap();
    assert!(snap.sum("rskd_serve_requests_total") >= 3);
    assert!(
        snap.quantile_us("rskd_serve_latency_us", 0.5).is_some(),
        "latency histogram must have observations"
    );

    // GetTrace: a traced request's Server span comes back over the wire
    let trace = obs::mint_trace();
    {
        let root =
            obs::SpanScope::begin(obs::spans(), obs::SpanKind::Root, trace, 0, u32::MAX, 4, 8);
        let mut block = RangeBlock::new();
        client.read_range_into(4, 8, &mut block).unwrap();
        root.finish();
    }
    let spans = client.trace_spans().unwrap();
    assert!(
        spans.iter().any(|s| s.trace == trace && s.kind == obs::SpanKind::Server),
        "the traced request's server span must be in the wire dump"
    );

    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}
