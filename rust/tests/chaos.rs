//! Chaos suite (docs/RESILIENCE.md): every injected fault class — delay,
//! connection drop, stalled mid-frame write, torn shard read, member kill —
//! must end in a typed error, a served fallback, or a byte-identical hedged
//! answer. Never a hang, never wrong probabilities.
//!
//! Tests that install the process-global fault plan serialize on
//! [`fault::test_mutex`] and scope the plan with [`ScopedPlan`] so a
//! panicking test cannot leak faults into the next. Fault schedules are
//! seed-keyed and replayable; the replay test pins that bit-for-bit.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rskd::cache::{CacheReader, CacheWriter, ProbCodec, RangeBlock, SparseTarget, TargetSource};
use rskd::cluster::{ClusterControl, ClusterManifest, ClusterReader, ShardSpec};
use rskd::fault::{self, FaultPlan, FaultRule, FaultSite, ScopedPlan};
use rskd::serve::{Endpoint, RangeRead, ServeClient, ServeConfig, Server, NO_EPOCH};

fn tdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn target_for(pos: u64) -> SparseTarget {
    SparseTarget {
        ids: vec![pos as u32 % 89, 150 + (pos as u32 % 11), 300],
        probs: vec![25.0 / 50.0, 15.0 / 50.0, 5.0 / 50.0],
    }
}

/// `n` positions in shards of 16, tagged as an RS-50 cache.
fn build_cache(dir: &std::path::Path, n: u64) {
    let w = CacheWriter::create_with_kind(
        dir,
        ProbCodec::Count { rounds: 50 },
        16,
        32,
        Some("rs:rounds=50,temp=1".into()),
    )
    .unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_for(pos)));
    }
    w.finish().unwrap();
}

fn start_standalone(dir: &std::path::Path) -> Server {
    let reader = Arc::new(CacheReader::open(dir).unwrap());
    Server::start(
        reader,
        Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0))),
        ServeConfig::default(),
    )
    .unwrap()
}

fn start_member(
    dir: &std::path::Path,
    manifest: &ClusterManifest,
    me: Endpoint,
) -> (Server, Arc<ClusterControl>) {
    let reader = Arc::new(CacheReader::open(dir).unwrap());
    let control = Arc::new(ClusterControl::new(manifest.clone(), me.clone()));
    let server =
        Server::start_cluster(reader, me, ServeConfig::default(), Arc::clone(&control)).unwrap();
    (server, control)
}

/// A single shard `[0, n)` replicated on both endpoints: every request has
/// somewhere to hedge and somewhere to fail over.
fn replicated_manifest(n: u64, a: &Endpoint, b: &Endpoint) -> ClusterManifest {
    ClusterManifest::new(
        1,
        vec![ShardSpec { lo: 0, hi: n, endpoints: vec![a.clone(), b.clone()] }],
    )
    .unwrap()
}

// ---------------------------------------------------------------------------
// Fault class: delay (per-reader plan, the `set_load_delay` fold-in)
// ---------------------------------------------------------------------------

#[test]
fn load_delay_compat_slows_cold_reads_only() {
    let dir = tdir("load-delay");
    build_cache(&dir, 64);
    let reader = CacheReader::open(&dir).unwrap();
    reader.set_load_delay(Duration::from_millis(40));
    // the compat wrapper is a rule on the per-reader plan, not a bespoke knob
    assert_eq!(
        reader.faults().rule(FaultSite::CacheLoadDelay),
        FaultRule::always_delay(Duration::from_millis(40))
    );

    let t0 = Instant::now();
    let cold = reader.try_get_range(0, 16).unwrap();
    assert!(t0.elapsed() >= Duration::from_millis(40), "cold read skipped the injected delay");
    assert_eq!(cold[0], target_for(0), "delayed read must still answer correct bytes");

    // the decoded shard is cached: the delay site is not consulted again
    let t1 = Instant::now();
    assert_eq!(reader.try_get_range(0, 16).unwrap(), cold);
    assert!(t1.elapsed() < Duration::from_millis(40), "warm read must not re-fire the delay");
    assert_eq!(reader.faults().snapshot().fired[FaultSite::CacheLoadDelay.index()], 1);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Fault class: torn read (per-reader plan)
// ---------------------------------------------------------------------------

#[test]
fn torn_shard_read_is_typed_never_wrong_bytes() {
    let dir = tdir("torn");
    build_cache(&dir, 64);
    let reader = CacheReader::open(&dir).unwrap();
    reader.faults().set_rule(FaultSite::CacheTornRead, FaultRule::every_nth(1, 0));

    // every load hands the decoder a truncated shard image: the outcome is
    // a typed error — truncated data must never decode into probabilities
    for _ in 0..3 {
        let err = reader.try_get_range(0, 16).unwrap_err();
        assert_ne!(err.kind(), std::io::ErrorKind::TimedOut, "torn read is not a timeout: {err}");
    }
    assert_eq!(reader.faults().snapshot().fired[FaultSite::CacheTornRead.index()], 3);

    // a failed load is not cached: disarming the site heals the reader
    reader.faults().set_rule(FaultSite::CacheTornRead, FaultRule::never());
    let healed = reader.try_get_range(0, 16).unwrap();
    let fresh = CacheReader::open(&dir).unwrap();
    assert_eq!(healed, fresh.get_range(0, 16), "healed read must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Fault classes: connection drop + stalled mid-frame write (global plan)
// ---------------------------------------------------------------------------

#[test]
fn server_drops_and_stalls_are_absorbed_by_reconnect_resend() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("drop-stall");
    build_cache(&dir, 128);
    let server = start_standalone(&dir);
    let direct = CacheReader::open(&dir).unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();

    let scoped = ScopedPlan::install(
        FaultPlan::new(11)
            .with(FaultSite::ServerConnDrop, FaultRule::every_nth(3, 0))
            .with(FaultSite::ServerStallWrite, FaultRule::every_nth(4, 0)),
    );
    // the server hangs up before (or mid-) response on a fixed schedule;
    // every read must still land byte-identical via reconnect-resend
    for i in 0..24u64 {
        let start = (i * 7) % 100;
        let r = client.read_range_at(start, 16, NO_EPOCH, &mut block).unwrap();
        assert!(matches!(r, RangeRead::Targets { .. }), "{r:?}");
        assert_eq!(block.to_targets(), direct.get_range(start, 16), "read {i}");
    }
    let snap = scoped.plan().snapshot();
    assert!(
        snap.fired[FaultSite::ServerConnDrop.index()] >= 3,
        "drop schedule never fired: {snap:?}"
    );
    assert!(
        snap.fired[FaultSite::ServerStallWrite.index()] >= 3,
        "stall schedule never fired: {snap:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn client_conn_drops_are_absorbed_by_reconnect_resend() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("client-drop");
    build_cache(&dir, 128);
    let server = start_standalone(&dir);
    let direct = CacheReader::open(&dir).unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();

    let scoped = ScopedPlan::install(
        FaultPlan::new(13).with(FaultSite::ClientConnDrop, FaultRule::every_nth(2, 0)),
    );
    for i in 0..12u64 {
        let start = (i * 9) % 100;
        let r = client.read_range_at(start, 16, NO_EPOCH, &mut block).unwrap();
        assert!(matches!(r, RangeRead::Targets { .. }), "{r:?}");
        assert_eq!(block.to_targets(), direct.get_range(start, 16), "read {i}");
    }
    assert!(scoped.plan().snapshot().fired[FaultSite::ClientConnDrop.index()] >= 6);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Deadlines: expired budgets are typed, shed jobs are counted
// ---------------------------------------------------------------------------

#[test]
fn client_deadline_expiry_is_typed_timeout() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("deadline-client");
    build_cache(&dir, 64);
    let server = start_standalone(&dir);
    let direct = CacheReader::open(&dir).unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();
    // prime the connection (and the shard cache) before injecting anything
    client.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap();

    let _scoped = ScopedPlan::install(
        FaultPlan::new(17)
            .with(FaultSite::ServeJobDelay, FaultRule::always_delay(Duration::from_millis(80))),
    );
    client.deadline = Some(Duration::from_millis(15));
    let t0 = Instant::now();
    let err = client.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(
        t0.elapsed() < Duration::from_millis(80),
        "an expired budget must not wait out the straggler"
    );

    // with the budget removed and the site disarmed the client recovers
    fault::plan().unwrap().set_rule(FaultSite::ServeJobDelay, FaultRule::never());
    client.deadline = None;
    let r = client.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { .. }), "{r:?}");
    assert_eq!(block.to_targets(), direct.get_range(0, 16));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_expired_connection_never_serves_stale_response() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("deadline-desync");
    build_cache(&dir, 64);
    let server = start_standalone(&dir);
    let direct = CacheReader::open(&dir).unwrap();
    let mut client = ServeClient::connect(server.endpoint()).unwrap();
    let mut block = RangeBlock::new();
    client.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap();

    // the request for [0, 16) is written, then the budget dies while the
    // server is still sleeping on the injected delay — the response is now
    // in flight toward a connection the client has already given up on
    let _scoped = ScopedPlan::install(
        FaultPlan::new(37)
            .with(FaultSite::ServeJobDelay, FaultRule::always_delay(Duration::from_millis(80))),
    );
    client.deadline = Some(Duration::from_millis(15));
    let err = client.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");

    // let the stale [0, 16) response land in the socket buffer, then ask
    // the SAME client for a different range of the same length: the wire
    // has no request ids, so reusing the stream would decode the stale
    // frame as this answer — silently wrong bytes. The client must poison
    // and reconnect instead.
    fault::plan().unwrap().set_rule(FaultSite::ServeJobDelay, FaultRule::never());
    client.deadline = None;
    std::thread::sleep(Duration::from_millis(120));
    let r = client.read_range_at(16, 16, NO_EPOCH, &mut block).unwrap();
    assert!(matches!(r, RangeRead::Targets { .. }), "{r:?}");
    assert_eq!(
        block.to_targets(),
        direct.get_range(16, 16),
        "a reused connection served the previous request's stale response"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn server_sheds_queue_expired_jobs_typed_and_counted() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("deadline-shed");
    build_cache(&dir, 64);
    // one worker: a delayed job in front of the queue starves the one behind
    let reader = Arc::new(CacheReader::open(&dir).unwrap());
    let server = Server::start(
        reader,
        Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0))),
        ServeConfig { workers: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let ep = server.endpoint().clone();
    let mut warm = ServeClient::connect(&ep).unwrap();
    let mut block = RangeBlock::new();
    warm.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap();

    let _scoped = ScopedPlan::install(
        FaultPlan::new(19)
            .with(FaultSite::ServeJobDelay, FaultRule::always_delay(Duration::from_millis(120))),
    );
    // A (no deadline) occupies the worker for 120ms; B's 25ms budget expires
    // in queue, so the worker sheds B's job typed instead of serving it late
    let blocker = std::thread::spawn({
        let ep = ep.clone();
        move || {
            let mut a = ServeClient::connect(&ep).unwrap();
            let mut block = RangeBlock::new();
            a.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap();
        }
    });
    std::thread::sleep(Duration::from_millis(20));
    let mut b = ServeClient::connect(&ep).unwrap();
    b.deadline = Some(Duration::from_millis(25));
    let err = b.read_range_at(0, 16, NO_EPOCH, &mut block).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    blocker.join().unwrap();

    // the shed is visible server-side (the worker popped B after expiry)
    let t0 = Instant::now();
    while server.stats_snapshot().deadline_exceeded == 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "shed was never counted");
        std::thread::sleep(Duration::from_millis(10));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Hedged reads: a straggling replica is raced, bytes stay identical
// ---------------------------------------------------------------------------

#[test]
fn hedged_read_beats_injected_straggler_byte_identical() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("hedge");
    build_cache(&dir, 160);
    let (a, b) = (
        Endpoint::Unix(dir.join("a.sock")),
        Endpoint::Unix(dir.join("b.sock")),
    );
    let manifest = replicated_manifest(160, &a, &b);
    let (_sa, _ca) = start_member(&dir, &manifest, a);
    let (_sb, _cb) = start_member(&dir, &manifest, b);
    let reader = ClusterReader::from_manifest(manifest).unwrap();
    let direct = CacheReader::open(&dir).unwrap();

    // plan installed but inactive: the warm pass arms the p95 hedge delay
    // without advancing any fault clock
    let scoped = ScopedPlan::install(FaultPlan::new(23));
    for i in 0..24u64 {
        let start = (i * 5) % 120;
        assert_eq!(reader.try_get_range(start, 24).unwrap(), direct.get_range(start, 24));
    }
    let delay = reader.hedge_delay().expect("hedge delay must arm after 24 samples");
    assert!(delay >= Duration::from_millis(1), "delay clamps at the 1ms floor: {delay:?}");

    // every 2nd job straggles 60ms — far past the hedge delay, so the
    // re-issued segment on the other replica answers first
    scoped
        .plan()
        .set_rule(FaultSite::ServeJobDelay, FaultRule::every_nth(2, 60_000));
    let mut i = 0u64;
    while reader.counters().hedges_won == 0 {
        assert!(i < 40, "no hedge won in {i} reads: {:?}", reader.counters());
        let start = (i * 5) % 120;
        assert_eq!(
            reader.try_get_range(start, 24).unwrap(),
            direct.get_range(start, 24),
            "hedged read {i} must stay byte-identical"
        );
        i += 1;
    }
    let c = reader.counters();
    assert!(c.hedges_launched >= c.hedges_won, "{c:?}");
    assert_eq!(c.deadline_exceeded, 0, "no deadline was set: {c:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Cluster deadlines: the budget decomposes across routing and is typed
// ---------------------------------------------------------------------------

#[test]
fn cluster_deadline_budget_is_typed_and_counted() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("deadline-cluster");
    build_cache(&dir, 96);
    let a = Endpoint::Unix(dir.join("a.sock"));
    let manifest =
        ClusterManifest::new(1, vec![ShardSpec { lo: 0, hi: 96, endpoints: vec![a.clone()] }])
            .unwrap();
    let (_sa, _ca) = start_member(&dir, &manifest, a);
    let reader = ClusterReader::from_manifest(manifest).unwrap();
    let direct = CacheReader::open(&dir).unwrap();
    assert_eq!(reader.try_get_range(0, 32).unwrap(), direct.get_range(0, 32));

    let scoped = ScopedPlan::install(
        FaultPlan::new(29)
            .with(FaultSite::ServeJobDelay, FaultRule::always_delay(Duration::from_millis(90))),
    );
    reader.set_deadline(Some(Duration::from_millis(25)));
    let t0 = Instant::now();
    let err = reader.try_get_range(0, 32).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "deadline must bound the whole fan-out, not just one socket read"
    );
    assert!(reader.counters().deadline_exceeded >= 1, "{:?}", reader.counters());

    // lifting the budget (and the fault) restores byte-identical service
    scoped.plan().set_rule(FaultSite::ServeJobDelay, FaultRule::never());
    reader.set_deadline(None);
    assert_eq!(reader.try_get_range(0, 32).unwrap(), direct.get_range(0, 32));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Fault class: member kill — breaker trips, probe re-admits
// ---------------------------------------------------------------------------

#[test]
fn member_kill_trips_breaker_and_probe_readmits() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());
    let dir = tdir("breaker");
    build_cache(&dir, 160);
    let (a, b) = (
        Endpoint::Unix(dir.join("a.sock")),
        Endpoint::Unix(dir.join("b.sock")),
    );
    let manifest = replicated_manifest(160, &a, &b);
    let (_sa, _ca) = start_member(&dir, &manifest, a);
    let (sb, _cb) = start_member(&dir, &manifest, b.clone());
    let reader = ClusterReader::from_manifest(manifest.clone()).unwrap();
    let direct = CacheReader::open(&dir).unwrap();

    // the kill moment comes off the seeded MemberKill schedule, same as
    // `load-gen --chaos`: the driver consults the site, the data path never
    let scoped =
        ScopedPlan::install(FaultPlan::new(31).with(FaultSite::MemberKill, FaultRule::every_nth(5, 0)));
    let mut sb = Some(sb);
    for i in 0..24u64 {
        if sb.is_some() && fault::fires(FaultSite::MemberKill) {
            drop(sb.take()); // kill member B mid-run
        }
        let start = (i * 7) % 120;
        assert_eq!(
            reader.try_get_range(start, 24).unwrap(),
            direct.get_range(start, 24),
            "read {i} around the kill must be served by the survivor"
        );
    }
    assert!(sb.is_none(), "MemberKill never fired in 24 driver laps");
    let c = reader.counters();
    assert!(c.failovers >= 1, "the dead member was never skipped: {c:?}");
    assert!(c.breaker_trips >= 1, "3 consecutive failures must trip the breaker: {c:?}");
    assert_eq!(c.breaker_recoveries, 0, "nothing to recover yet: {c:?}");
    let trips_when_open = c.failovers;

    // while the breaker is open the dead endpoint is out of rotation:
    // traffic keeps flowing without new connect attempts piling up failures
    for i in 0..8u64 {
        let start = (i * 13) % 120;
        assert_eq!(reader.try_get_range(start, 24).unwrap(), direct.get_range(start, 24));
    }

    // restart B on the same endpoint; after the cooldown a half-open Ping
    // probe must re-admit it — and reads stay byte-identical throughout
    if let Endpoint::Unix(p) = &b {
        let _ = std::fs::remove_file(p);
    }
    let (_sb2, _cb2) = start_member(&dir, &manifest, b);
    std::thread::sleep(Duration::from_millis(300)); // > BREAKER_COOLDOWN
    let t0 = Instant::now();
    let mut i = 0u64;
    while reader.counters().breaker_recoveries == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "breaker never recovered: {:?}",
            reader.counters()
        );
        let start = (i * 11) % 120;
        assert_eq!(reader.try_get_range(start, 24).unwrap(), direct.get_range(start, 24));
        i += 1;
    }
    let after = reader.counters();
    assert!(after.breaker_recoveries >= 1, "{after:?}");
    assert!(
        after.failovers >= trips_when_open,
        "failovers only grow while the member is actually down: {after:?}"
    );
    drop(scoped);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Deterministic replay: same seed ⇒ same schedule ⇒ same outcome counters
// ---------------------------------------------------------------------------

#[test]
fn same_seed_replays_same_faults_and_same_outcomes() {
    let _serial = fault::test_mutex().lock().unwrap_or_else(|e| e.into_inner());

    // one full client/server workload under a seeded plan; returns the
    // fault snapshot plus every outcome a run can observe
    let run = |tag: &str, seed: u64| -> (fault::FaultSnapshot, u64, Vec<Vec<SparseTarget>>) {
        let dir = tdir(tag);
        build_cache(&dir, 128);
        let server = start_standalone(&dir);
        let mut client = ServeClient::connect(server.endpoint()).unwrap();
        let mut block = RangeBlock::new();
        let scoped = ScopedPlan::install(
            FaultPlan::new(seed)
                .with(FaultSite::ServerConnDrop, FaultRule::every_nth(5, 0))
                .with(FaultSite::ClientConnDrop, FaultRule::every_nth(4, 0))
                .with(FaultSite::ServeJobDelay, FaultRule::with_prob(0.25, 500)),
        );
        let mut outputs = Vec::new();
        let mut ok = 0u64;
        for i in 0..16u64 {
            let start = (i * 11) % 100;
            let r = client.read_range_at(start, 12, NO_EPOCH, &mut block).unwrap();
            assert!(matches!(r, RangeRead::Targets { .. }), "{r:?}");
            outputs.push(block.to_targets());
            ok += 1;
        }
        let snap = scoped.plan().snapshot();
        drop(scoped);
        let _ = std::fs::remove_dir_all(&dir);
        (snap, ok, outputs)
    };

    let (snap1, ok1, out1) = run("replay-1", 77);
    let (snap2, ok2, out2) = run("replay-2", 77);
    assert_eq!(snap1, snap2, "same seed must replay the identical fault schedule");
    assert_eq!(ok1, ok2);
    assert_eq!(out1, out2, "replayed runs must serve identical bytes");
    assert!(snap1.total_fired() > 0, "the workload never exercised a fault: {snap1:?}");
    // the injected-delay draw is probabilistic per ordinal but seed-keyed;
    // at least one job per read was consulted (drop-triggered resends add
    // more — identically in both runs, per the snapshot equality above)
    assert!(snap1.decisions[FaultSite::ServeJobDelay.index()] >= 16, "{snap1:?}");
}
