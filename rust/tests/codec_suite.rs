//! Codec suite for the v3 compressed shard tier (docs/CACHE_FORMAT.md
//! §Codec): property roundtrips over every cache kind × shard codec,
//! corruption fuzz (truncations, bit flips, lying manifests) that must
//! surface typed [`CacheError`]s and never silently decode wrong
//! probabilities, golden v2/v3 byte fixtures under `rust/tests/fixtures/`,
//! and served bit-exactness over raw vs compressed directories. The
//! corruption sweeps and serve exchange run under both reader I/O modes
//! ([`IoMode::Mapped`] / [`IoMode::Heap`]) — the mmap'd fast path must
//! reject torn files with the same typed errors as the heap fallback and
//! never fault past a short mapping.
//!
//! Runs twice in CI: default features, and `--features zstd` to include
//! [`ShardCodec::DeltaPackedZstd`].

use std::path::{Path, PathBuf};
use std::sync::Arc;

use rskd::cache::format::{read_header, CacheManifest, Shard, FLAG_FULLY_COVERED};
use rskd::cache::{
    cache_error_of, mapio, CacheError, CacheReader, CacheWriter, IoMode, ProbCodec, RangeBlock,
    ReadOptions, ShardCodec, SparseTarget,
};
use rskd::serve::{Endpoint, ServeClient, ServeConfig, Server};
use rskd::util::rng::Pcg;
use rskd::util::testing::forall;

const CODEC: ProbCodec = ProbCodec::Count { rounds: 50 };
const KIND: &str = "rs:rounds=50,temp=1";
const MAX_ID: u32 = (1 << 17) - 1;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskd-codec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures")
}

/// The non-raw codecs compiled into this build (CI runs the suite with and
/// without the `zstd` feature).
fn compressing_codecs() -> Vec<ShardCodec> {
    let mut v = vec![ShardCodec::Delta, ShardCodec::DeltaPacked, ShardCodec::DeltaPackedLz];
    if cfg!(feature = "zstd") {
        v.push(ShardCodec::DeltaPackedZstd);
    }
    v
}

/// One record with `shape` slots: ids ascending in the 17-bit space with a
/// forced gap ≥ 2^16 whenever there are two or more slots, probs exact
/// multiples of 1/50 (lossless under `Count {{ rounds: 50 }}`).
fn record_of_shape(rng: &mut Pcg, shape: usize) -> SparseTarget {
    let mut ids: Vec<u32> = (0..shape).map(|_| rng.next_u32() & MAX_ID).collect();
    if ids.len() >= 2 {
        ids[0] = rng.next_u32() % 100; // head low, tail high: gap >= 2^16
        let last = ids.len() - 1;
        ids[last] = 70_000 + rng.next_u32() % (MAX_ID - 70_000);
    }
    ids.sort_unstable();
    ids.dedup();
    let probs: Vec<f32> = ids.iter().map(|_| (rng.next_u32() % 51) as f32 / 50.0).collect();
    SparseTarget { ids, probs }
}

/// Slot-count shapes covering the satellite cases: empty positions,
/// single-slot rows, max-k (255-slot) rows, and ordinary rows.
fn fuzz_shape(rng: &mut Pcg) -> usize {
    match rng.usize_below(6) {
        0 => 0,
        1 => 1,
        2 => 255,
        _ => 1 + rng.usize_below(60),
    }
}

/// Deterministic position-keyed target for directory builds.
fn target_at(pos: u64) -> SparseTarget {
    let mut rng = Pcg::new(Pcg::mix_seed(0xC0DEC, pos));
    let shape = fuzz_shape(&mut rng);
    record_of_shape(&mut rng, shape)
}

fn build_dir(dir: &Path, shard_codec: ShardCodec, n: u64, pps: usize) {
    let w =
        CacheWriter::create_coded(dir, CODEC, shard_codec, pps, 64, Some(KIND.into())).unwrap();
    for pos in 0..n {
        assert!(w.push(pos, target_at(pos)));
    }
    w.finish().unwrap();
}

fn read_all(dir: &Path, n: usize) -> RangeBlock {
    let mut block = RangeBlock::new();
    CacheReader::open(dir).unwrap().read_range_into(0, n, &mut block).unwrap();
    block
}

// ---------------------------------------------------------------------------
// property roundtrips (satellite: every CacheKind × every codec)
// ---------------------------------------------------------------------------

/// Shard-file roundtrip property: random record sets — empty positions,
/// single-slot rows, max-k rows, ≥2^16 id gaps — survive every prob codec
/// (`topk` caches use Ratio, `rs:*` caches use Count) × every shard codec
/// with records preserved exactly; Raw through the coded entry point stays
/// byte-identical to the v2 stream.
#[test]
fn property_shard_roundtrip_every_kind_and_codec() {
    forall(
        24,
        |rng| {
            let shapes: Vec<usize> = (0..rng.usize_below(9)).map(|_| fuzz_shape(rng)).collect();
            (shapes, rng.next_u32() as u64)
        },
        |(shapes, seed)| {
            for codec in [ProbCodec::Interval, ProbCodec::Ratio, ProbCodec::Count { rounds: 50 }]
            {
                let mut shard = Shard::new(codec, 96);
                let mut rng = Pcg::new(*seed);
                for &n in shapes {
                    shard.push(&record_of_shape(&mut rng, n));
                }
                for sc in compressing_codecs() {
                    let mut buf = Vec::new();
                    shard.write_to_coded(&mut buf, FLAG_FULLY_COVERED, sc).unwrap();
                    let hdr = read_header(&mut buf.as_slice()).unwrap();
                    if hdr.version != 3 || hdr.shard_codec != sc {
                        return Err(format!("{codec:?}/{sc}: bad header {hdr:?}"));
                    }
                    let back = Shard::read_from(&mut buf.as_slice()).unwrap();
                    if back.records != shard.records || back.start != shard.start {
                        return Err(format!("{codec:?}/{sc}: records changed in roundtrip"));
                    }
                }
                let (mut coded, mut raw) = (Vec::new(), Vec::new());
                shard.write_to_coded(&mut coded, 0, ShardCodec::Raw).unwrap();
                shard.write_to(&mut raw).unwrap();
                if coded != raw {
                    return Err(format!("{codec:?}: Raw coded stream diverged from v2"));
                }
            }
            Ok(())
        },
    );
}

/// Directory-level bit-exactness per cache kind: a compressed directory's
/// decoded `RangeBlock`s — full range, shard-spanning sub-ranges, and the
/// partial tail shard — are identical to the raw directory's, and the
/// manifest records the codec at version 3.
#[test]
fn directory_decode_bit_identical_per_kind() {
    let (n, pps) = (120u64, 32usize); // 3 full shards + a partial 24-position tail
    for (kind, codec) in [(Some(KIND.to_string()), CODEC), (Some("topk".into()), ProbCodec::Ratio)]
    {
        let raw_dir = tmp_dir(&format!("dir-raw-{}", codec.tag()));
        let w = CacheWriter::create_coded(
            &raw_dir,
            codec,
            ShardCodec::Raw,
            pps,
            64,
            kind.clone(),
        )
        .unwrap();
        for pos in 0..n {
            assert!(w.push(pos, target_at(pos)));
        }
        w.finish().unwrap();
        let raw = CacheReader::open(&raw_dir).unwrap();

        for sc in compressing_codecs() {
            let cdir = tmp_dir(&format!("dir-{sc}-{}", codec.tag()));
            let w =
                CacheWriter::create_coded(&cdir, codec, sc, pps, 64, kind.clone()).unwrap();
            for pos in 0..n {
                assert!(w.push(pos, target_at(pos)));
            }
            let stats = w.finish().unwrap();
            assert_eq!(stats.positions, n);

            let m = CacheManifest::load(&cdir).unwrap();
            assert_eq!((m.version, m.shard_codec), (3, sc));
            let r = CacheReader::open(&cdir).unwrap();
            assert_eq!(r.shard_codec, sc);
            for (start, len) in [(0u64, n as usize), (25, 40), (96, 24), (110, 30)] {
                let (mut a, mut b) = (RangeBlock::new(), RangeBlock::new());
                raw.read_range_into(start, len, &mut a).unwrap();
                r.read_range_into(start, len, &mut b).unwrap();
                assert_eq!(a, b, "{sc} [{start}, +{len}) must be bit-identical to raw");
            }
            let _ = std::fs::remove_dir_all(&cdir);
        }
        let _ = std::fs::remove_dir_all(&raw_dir);
    }
}

/// A coded build interrupted mid-shard resumes to a directory byte-identical
/// to a one-shot coded build — v3 crash recovery (manifest-less scan, CRC
/// validation, codec adoption) composes with the resumable-build contract.
#[test]
fn interrupted_coded_build_resumes_byte_identical() {
    let (n, pps, sc) = (90u64, 32usize, ShardCodec::DeltaPackedLz);
    let golden = tmp_dir("resume-golden");
    build_dir(&golden, sc, n, pps);

    let resumed = tmp_dir("resume-crash");
    let w = CacheWriter::create_coded(&resumed, CODEC, sc, pps, 64, Some(KIND.into())).unwrap();
    for pos in 0..40u64 {
        assert!(w.push(pos, target_at(pos)));
    }
    while w.backlog() > 0 {
        std::thread::yield_now();
    }
    std::thread::sleep(std::time::Duration::from_millis(50));
    w.abort(); // no trailing flush, no manifest
    assert!(!resumed.join("index.json").exists());

    // an untagged resume adopts the codec from the surviving v3 shards; a
    // conflicting tag is refused before any bytes are written
    let err = match CacheWriter::resume_coded(
        &resumed,
        CODEC,
        Some(ShardCodec::Delta),
        pps,
        64,
        Some(KIND.into()),
    ) {
        Err(e) => e,
        Ok(_) => panic!("conflicting codec must be refused"),
    };
    assert!(err.to_string().contains("refusing to mix shard codecs"), "{err}");
    let (w, coverage) =
        CacheWriter::resume_coded(&resumed, CODEC, None, pps, 64, Some(KIND.into())).unwrap();
    assert!(coverage.covers(0, 32), "completed shard must be recovered from its CRC'd file");
    for pos in 0..n {
        if !coverage.contains(pos) {
            assert!(w.push(pos, target_at(pos)));
        }
    }
    w.finish().unwrap();

    let files = |dir: &Path| -> Vec<(String, Vec<u8>)> {
        let mut v: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (e.file_name().into_string().unwrap(), std::fs::read(e.path()).unwrap())
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    };
    assert_eq!(files(&golden), files(&resumed), "resumed coded build must be byte-identical");
    let _ = std::fs::remove_dir_all(&golden);
    let _ = std::fs::remove_dir_all(&resumed);
}

// ---------------------------------------------------------------------------
// corruption fuzz (satellite: truncations, bit flips, lying manifests)
// ---------------------------------------------------------------------------

/// The two reader I/O modes the corruption sweeps run under: the mmap'd
/// fast path and the heap fallback must reject identical corruption with
/// identical typed errors — and a truncated *mapped* shard must fail the
/// pre-map length check, never SIGBUS past the end of a short mapping.
const IO_MODES: [IoMode; 2] = [IoMode::Mapped, IoMode::Heap];

/// Read the whole directory through a fresh reader (the LRU would otherwise
/// hide on-disk corruption behind a cached shard) in the given I/O mode.
fn try_read_all_io(dir: &Path, n: usize, io: IoMode) -> std::io::Result<RangeBlock> {
    let mut block = RangeBlock::new();
    CacheReader::open_with(dir, ReadOptions { io, ..ReadOptions::default() })?
        .read_range_into(0, n, &mut block)?;
    Ok(block)
}

fn try_read_all(dir: &Path, n: usize) -> std::io::Result<RangeBlock> {
    try_read_all_io(dir, n, IoMode::default())
}

/// Every truncation and every bit flip of a compressed shard file either
/// fails with a *typed* [`CacheError`] or (never observed, but permitted)
/// decodes bit-identically — wrong probabilities can never come out of a
/// torn or flipped v3 shard, and nothing panics, on the mapped path and
/// the heap fallback alike.
#[test]
fn corruption_fuzz_compressed_shard_never_misdecodes() {
    let (n, pps) = (12u64, 16usize); // one shard, small enough to sweep
    let dir = tmp_dir("fuzz");
    build_dir(&dir, ShardCodec::DeltaPackedLz, n, pps);
    let golden = read_all(&dir, n as usize);
    let manifest = CacheManifest::load(&dir).unwrap();
    let shard_path = dir.join(&manifest.shards[0].file);
    let pristine = std::fs::read(&shard_path).unwrap();

    let mut verdict = |bytes: &[u8], what: String| {
        std::fs::write(&shard_path, bytes).unwrap();
        for io in IO_MODES {
            match try_read_all_io(&dir, n as usize, io) {
                Ok(block) => {
                    assert_eq!(block, golden, "{what} ({io:?}): silently decoded wrong data")
                }
                Err(e) => assert!(
                    cache_error_of(&e).is_some(),
                    "{what} ({io:?}): untyped error `{e}` (kind {:?})",
                    e.kind()
                ),
            }
        }
    };
    // every truncation point
    for cut in 0..pristine.len() {
        verdict(&pristine[..cut], format!("truncated to {cut} bytes"));
    }
    // every bit of the header + length/checksum trailer; one rotating bit
    // per payload byte (any payload flip is a CRC mismatch regardless of bit)
    for i in 0..pristine.len() {
        let bits: &[u8] = if i < 32 { &[0, 1, 2, 3, 4, 5, 6, 7] } else { &[(i % 8) as u8] };
        for &bit in bits {
            let mut bad = pristine.clone();
            bad[i] ^= 1 << bit;
            verdict(&bad, format!("byte {i} bit {bit} flipped"));
        }
    }
    std::fs::write(&shard_path, &pristine).unwrap();

    // a lying manifest: the codec tag says delta, the shards are
    // delta-packed-lz — refused as a mismatch, not decoded as garbage
    let index = dir.join("index.json");
    let text = std::fs::read_to_string(&index).unwrap();
    assert!(text.contains("\"shard_codec\":\"delta-packed-lz\""), "{text}");
    std::fs::write(&index, text.replace("delta-packed-lz", "delta")).unwrap();
    let err = try_read_all(&dir, n as usize).unwrap_err();
    assert!(
        matches!(
            cache_error_of(&err),
            Some(CacheError::ShardCodecMismatch {
                expected: ShardCodec::Delta,
                found: ShardCodec::DeltaPackedLz,
            })
        ),
        "got: {err}"
    );
    // an unknown codec name in the manifest is a typed refusal at open
    std::fs::write(&index, text.replace("delta-packed-lz", "brotli")).unwrap();
    let err = try_read_all(&dir, n as usize).unwrap_err();
    assert!(
        matches!(
            cache_error_of(&err),
            Some(CacheError::BadShardCodecName { name }) if name.as_str() == "brotli"
        ),
        "got: {err}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Raw v2 shards predate the CRC, but truncations must still surface as
/// typed errors (never a panic or a short silent decode) — and on the
/// mapped path the in-place decoder's bounds checks against the fstat'd
/// mapping length must catch every cut without touching a byte past the
/// mapping (no SIGBUS).
#[test]
fn corruption_fuzz_raw_shard_truncations_are_typed() {
    let (n, pps) = (12u64, 16usize);
    let dir = tmp_dir("fuzz-raw");
    build_dir(&dir, ShardCodec::Raw, n, pps);
    let manifest = CacheManifest::load(&dir).unwrap();
    assert_eq!(manifest.version, 2, "raw directories must stay v2");
    let shard_path = dir.join(&manifest.shards[0].file);
    let pristine = std::fs::read(&shard_path).unwrap();
    for cut in 0..pristine.len() {
        std::fs::write(&shard_path, &pristine[..cut]).unwrap();
        for io in IO_MODES {
            let err = try_read_all_io(&dir, n as usize, io).unwrap_err();
            assert!(
                cache_error_of(&err).is_some() || err.kind() == std::io::ErrorKind::InvalidData,
                "cut {cut} ({io:?}): untyped error `{err}`"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// golden byte fixtures (satellite: pinned v2 + v3 wire bytes)
// ---------------------------------------------------------------------------

/// The records every golden fixture encodes (Count{50}, start = 7): an empty
/// position, a single-slot row at the largest 17-bit id, and a row whose id
/// gaps include a ≥2^16 jump.
fn golden_records() -> Vec<(Vec<u32>, Vec<u8>)> {
    vec![
        (vec![], vec![]),
        (vec![MAX_ID], vec![50]),
        (vec![3, 70_000, 70_001, 100_000], vec![25, 13, 7, 5]),
    ]
}

fn golden_shard() -> Shard {
    let mut shard = Shard::new(CODEC, 7);
    shard.records = golden_records();
    shard
}

/// Decode a fixture and pin its semantic content: records, exact x/50
/// probabilities, header fields.
fn check_fixture_decodes(bytes: &[u8], sc: ShardCodec) {
    let hdr = read_header(&mut &bytes[..]).unwrap();
    assert_eq!(hdr.version, if sc == ShardCodec::Raw { 2 } else { 3 });
    assert_eq!(hdr.shard_codec, sc);
    assert_eq!(hdr.flags, FLAG_FULLY_COVERED);
    assert_eq!((hdr.start, hdr.count), (7, 3));
    let shard = Shard::read_from(&mut &bytes[..]).unwrap();
    assert_eq!(shard.records, golden_records(), "{sc}");
    let t = shard.decode(2);
    assert_eq!(t.ids, vec![3, 70_000, 70_001, 100_000]);
    let exact: Vec<f32> = [25u8, 13, 7, 5].iter().map(|&c| c as f32 / 50.0).collect();
    assert_eq!(t.probs, exact, "Count{{50}} decode must be exact x/50");
}

/// An mmap'd image of a fixture file must be byte-identical to a heap load
/// and decode to the same records — the golden bytes pin the mapped read
/// path exactly as they pin the buffered one.
fn check_fixture_mapped(path: &Path, want: &[u8]) {
    let mapped = mapio::load_file(path, IoMode::Mapped).unwrap();
    let heap = mapio::load_file(path, IoMode::Heap).unwrap();
    assert!(mapped.is_mapped() || cfg!(not(unix)));
    assert_eq!(mapped.as_slice(), want, "mapped image diverged from the golden bytes");
    assert_eq!(heap.as_slice(), want, "heap image diverged from the golden bytes");
    let a = Shard::read_from(&mut mapped.as_slice()).unwrap();
    let b = Shard::read_from(&mut heap.as_slice()).unwrap();
    assert_eq!(a.records, b.records, "mapped and heap decodes diverged");
}

/// The v2 fixture pins the legacy wire format: any byte drift in the raw
/// record stream is a format break for every pre-v3 cache on disk.
#[test]
fn golden_v2_fixture_pinned() {
    let path = fixtures_dir().join("golden_v2_count50.slc");
    let bytes = std::fs::read(&path).unwrap();
    check_fixture_decodes(&bytes, ShardCodec::Raw);
    check_fixture_mapped(&path, &bytes);
    let mut re = Vec::new();
    golden_shard().write_to_flagged(&mut re, FLAG_FULLY_COVERED).unwrap();
    assert_eq!(re, bytes, "v2 encoder drifted from the golden bytes");
}

/// The v3 fixtures pin the compressed wire formats byte-for-byte: varint /
/// zigzag layout, bit-packed counts, the rlz stream, the CRC trailer.
#[test]
fn golden_v3_fixtures_pinned() {
    for (file, sc) in [
        ("golden_v3_delta.slc", ShardCodec::Delta),
        ("golden_v3_delta_packed.slc", ShardCodec::DeltaPacked),
        ("golden_v3_delta_packed_lz.slc", ShardCodec::DeltaPackedLz),
    ] {
        let path = fixtures_dir().join(file);
        let bytes = std::fs::read(&path).unwrap();
        check_fixture_decodes(&bytes, sc);
        check_fixture_mapped(&path, &bytes);
        let mut re = Vec::new();
        golden_shard().write_to_coded(&mut re, FLAG_FULLY_COVERED, sc).unwrap();
        assert_eq!(re, bytes, "{sc} encoder drifted from {file}");
    }
}

/// The zstd fixture is readable only with the feature; without it the file
/// is *refused* (typed), never misread. With it, the stub's raw-block frame
/// is pinned byte-for-byte.
#[test]
fn golden_zstd_fixture_gated_by_feature() {
    let bytes = std::fs::read(fixtures_dir().join("golden_v3_delta_packed_zstd.slc")).unwrap();
    let hdr = read_header(&mut &bytes[..]).unwrap();
    assert_eq!(hdr.shard_codec, ShardCodec::DeltaPackedZstd);
    #[cfg(feature = "zstd")]
    {
        check_fixture_decodes(&bytes, ShardCodec::DeltaPackedZstd);
        let mut re = Vec::new();
        golden_shard()
            .write_to_coded(&mut re, FLAG_FULLY_COVERED, ShardCodec::DeltaPackedZstd)
            .unwrap();
        assert_eq!(re, bytes, "zstd stub encoder drifted from the golden bytes");
    }
    #[cfg(not(feature = "zstd"))]
    {
        let err = match Shard::read_from(&mut &bytes[..]) {
            Err(e) => e,
            Ok(_) => panic!("tag-4 shards must be refused without the zstd feature"),
        };
        assert!(
            matches!(cache_error_of(&err), Some(CacheError::ZstdUnavailable)),
            "got: {err}"
        );
        let mut out = Vec::new();
        let err = golden_shard()
            .write_to_coded(&mut out, FLAG_FULLY_COVERED, ShardCodec::DeltaPackedZstd)
            .unwrap_err();
        assert!(matches!(cache_error_of(&err), Some(CacheError::ZstdUnavailable)), "got: {err}");
    }
}

// ---------------------------------------------------------------------------
// served bit-exactness (tentpole acceptance: the wire is codec-invisible)
// ---------------------------------------------------------------------------

/// The scatter-written `Targets` frames (`Response::write_targets` /
/// `decode_targets_into`) stay bit-exact over compressed-origin shards AND
/// over both reader I/O modes: a server over a delta-packed-lz directory, a
/// server over an mmap'd raw directory, and a server forced onto the heap
/// fallback all answer every range with exactly the bytes a direct reader
/// produces.
#[test]
fn served_ranges_bit_identical_over_raw_and_compressed_dirs() {
    let (n, pps) = (96u64, 16usize);
    let raw_dir = tmp_dir("serve-raw");
    let lz_dir = tmp_dir("serve-lz");
    build_dir(&raw_dir, ShardCodec::Raw, n, pps);
    build_dir(&lz_dir, ShardCodec::DeltaPackedLz, n, pps);
    let direct = CacheReader::open(&raw_dir).unwrap();

    let open_io = |dir: &Path, io| {
        CacheReader::open_with(dir, ReadOptions { io, ..ReadOptions::default() }).unwrap()
    };
    let tcp0 = || Endpoint::Tcp(std::net::SocketAddr::from(([127, 0, 0, 1], 0)));
    let raw_srv = Server::start(
        Arc::new(open_io(&raw_dir, IoMode::Mapped)),
        tcp0(),
        ServeConfig::default(),
    )
    .unwrap();
    let heap_srv = Server::start(
        Arc::new(open_io(&raw_dir, IoMode::Heap)),
        tcp0(),
        ServeConfig::default(),
    )
    .unwrap();
    let lz_srv = Server::start(
        Arc::new(CacheReader::open(&lz_dir).unwrap()),
        tcp0(),
        ServeConfig::default(),
    )
    .unwrap();
    let mut raw_client = ServeClient::connect(raw_srv.endpoint()).unwrap();
    let mut heap_client = ServeClient::connect(heap_srv.endpoint()).unwrap();
    let mut lz_client = ServeClient::connect(lz_srv.endpoint()).unwrap();

    // shard-interior, shard-spanning, past-the-end, and full-stream ranges
    for (start, len) in [(0u64, 10usize), (12, 40), (90, 16), (0, n as usize)] {
        let from_raw = raw_client.get_range(start, len).unwrap();
        let from_heap = heap_client.get_range(start, len).unwrap();
        let from_lz = lz_client.get_range(start, len).unwrap();
        let local = direct.get_range(start, len);
        assert_eq!(from_lz, from_raw, "[{start}, +{len}): served bytes must match raw origin");
        assert_eq!(from_lz, local, "[{start}, +{len}): served bytes must match a direct read");
        assert_eq!(
            from_heap, from_raw,
            "[{start}, +{len}): heap-fallback serve must match the mapped serve"
        );
    }
    // the raw server's responses all went out on the writev scatter path
    // (on little-endian hosts; big-endian takes the copy fallback)
    if cfg!(target_endian = "little") {
        let snap = raw_srv.stats_snapshot();
        assert_eq!(
            snap.responses_vectored, snap.requests,
            "every Targets frame must be scatter-written"
        );
        assert!(snap.responses_vectored > 0);
    }
    drop(raw_srv);
    drop(heap_srv);
    drop(lz_srv);
    let _ = std::fs::remove_dir_all(&raw_dir);
    let _ = std::fs::remove_dir_all(&lz_dir);
}
