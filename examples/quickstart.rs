//! Quickstart: load the AOT artifacts, initialize a teacher and a student,
//! run one RS-KD training step end to end (teacher fwd -> L1 sampler ->
//! sparse train step), and print the losses.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use rskd::model::ModelState;
use rskd::runtime::{Engine, HostTensor};
use rskd::util::rng::Pcg;

fn main() -> Result<()> {
    let engine = Engine::load(std::path::Path::new("artifacts/small"))?;
    let m = engine.manifest();
    let (b, s, v, k, n) = (m.batch, m.seq, m.vocab, m.k_slots, m.n_rounds);
    println!("loaded config {:?}: batch {b}, seq {s}, vocab {v}", m.config);

    let teacher = ModelState::init(&engine, "teacher", 0)?;
    let mut student = ModelState::init(&engine, "student", 1)?;
    println!("teacher {} params, student {} params", teacher.param_count(), student.param_count());

    // a toy batch
    let mut rng = Pcg::new(42);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(v as u64) as i32).collect();
    let labels: Vec<i32> = (0..b * s).map(|_| rng.below(v as u64) as i32).collect();
    let toks_t = HostTensor::i32(tokens, &[b, s]);
    let labels_t = HostTensor::i32(labels, &[b, s]);

    // 1. teacher forward
    let probs = engine.call("fwd_teacher", &[teacher.params_tensor(), toks_t.clone()])?.remove(0);

    // 2. L1 Pallas importance sampler: 50 draws from q = p
    let mut unif = vec![0.0f32; b * s * n];
    rng.fill_f32(&mut unif);
    let mut sampled = engine.call(
        "sample_rs",
        &[probs, HostTensor::f32(unif, &[b, s, n]), HostTensor::scalar_f32(1.0)],
    )?;
    let weights = sampled.remove(1);
    let ids = sampled.remove(0);
    println!("sampled sparse targets: {} slots/position", n);

    // 3. student sparse-KD train step (pad N slots into the K-slot block)
    let ids_i = ids.as_i32()?;
    let w_f = weights.as_f32()?;
    let mut idx = vec![0i32; b * s * k];
    let mut val = vec![0.0f32; b * s * k];
    for r in 0..b * s {
        for j in 0..n.min(k) {
            idx[r * k + j] = ids_i[r * n + j];
            val[r * k + j] = w_f[r * n + j];
        }
    }
    let [p, mm, vv, st] = student.opt_inputs();
    let mut outs = engine.call(
        "train_sparse_student",
        &[
            p, mm, vv, st,
            HostTensor::scalar_f32(4e-4),
            toks_t,
            labels_t,
            HostTensor::i32(idx, &[b, s, k]),
            HostTensor::f32(val, &[b, s, k]),
            HostTensor::scalar_f32(0.0),                 // alpha (CE weight)
            HostTensor::f32(vec![0.0; b * s], &[b, s]),  // smoothing
            HostTensor::scalar_f32(0.0),                 // ghost token off
            HostTensor::f32(vec![1.0; b * s], &[b, s]),  // per-token LR scale
        ],
    )?;
    student.absorb(&mut outs)?;
    println!("one RS-KD step done: loss {:.4}, kd loss {:.4}, student step {}",
             outs[0].scalar()?, outs[1].scalar()?, student.step);
    println!("quickstart OK");
    Ok(())
}
