//! End-to-end driver (DESIGN.md deliverable): full offline-distillation
//! pipeline on a real (synthetic-corpus) workload —
//!   corpus -> BPE -> packing -> teacher CE pre-training -> quantized RS
//!   logit cache -> student RS-KD training (a few hundred steps) -> eval,
//! logging the loss curve and the headline metrics, compared against a CE
//! baseline trained with the same budget.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pretrain -- --steps 300
//! ```
//!
//! The method is a `DistillSpec` string (docs/SPEC.md): pass
//! `--method rs:rounds=25` to change the KD run.

use anyhow::Result;
use rskd::coordinator::{Pipeline, PipelineConfig};
use rskd::report::Report;
use rskd::spec::DistillSpec;
use rskd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = PipelineConfig {
        artifact_dir: args.str_or("artifacts", "artifacts/small").into(),
        target_tokens: args.usize_or("tokens", 260_000),
        teacher_steps: args.usize_or("teacher-steps", 300),
        student_steps: args.usize_or("steps", 300),
        eval_batches: 6,
        work_dir: "target/e2e".into(),
        ..Default::default()
    };
    let spec = DistillSpec::parse(&args.str_or("method", "rs:rounds=50"))?;
    let mut report = Report::new("e2e_pretrain", "End-to-end offline distillation run");
    report.meta("spec", spec.to_json());

    report.line("== stage 1: data + teacher pre-training ==");
    let mut pipe = Pipeline::prepare(cfg)?;
    report.line(format!(
        "teacher: {} params | CE loss {:.3} -> {:.3} over {} steps",
        pipe.teacher.param_count(),
        pipe.teacher_losses.first().unwrap(),
        pipe.teacher_losses.last().unwrap(),
        pipe.teacher_losses.len()
    ));

    match (spec.cache_plan(), pipe.ensure_cache(&spec)?) {
        (Some(plan), Some(handle)) => {
            report.line(format!("== stage 2: sparse logit cache ({plan}) =="));
            let stats = &handle.stats;
            report.line(format!(
                "cached {} positions | {:.1} avg unique tokens | {} bytes ({:.2} B/position, {:.2} b/logit-slot)",
                stats.cache.positions,
                stats.avg_unique_tokens,
                stats.cache.bytes,
                stats.cache.bytes as f64 / stats.cache.positions.max(1) as f64,
                8.0 * stats.cache.bytes as f64 / stats.cache.slots.max(1) as f64,
            ));
        }
        // ce / dense losses need no cache — the comparison below still runs
        _ => report.line(format!("== stage 2: skipped ({} is cache-free) ==", spec.name())),
    }

    report.line(format!("== stage 3: student training ({} vs CE baseline) ==", spec.name()));
    let (_, tr_kd, ev_kd) = pipe.run_spec(&spec, 3)?;
    let (_, tr_ce, ev_ce) = pipe.run_spec(&DistillSpec::ce(), 3)?;

    report.line(format!("loss curve ({} | CE), every 10 steps:", spec.name()));
    for (i, w) in tr_kd.losses.chunks(10).zip(tr_ce.losses.chunks(10)).enumerate() {
        let (a, b) = w;
        let ma = a.iter().sum::<f32>() / a.len() as f32;
        let mb = b.iter().sum::<f32>() / b.len() as f32;
        report.line(format!("  step {:>4}: {:.4} | {:.4}", i * 10, ma, mb));
    }

    report.line("== stage 4: evaluation ==");
    report.table(
        &["method", "LM loss", "ECE %", "SpecAccept %", "agree %", "tokens/s"],
        &[
            vec![format!("{} (cached)", spec.name()), format!("{:.3}", ev_kd.lm_loss),
                 format!("{:.1}", ev_kd.ece_pct), format!("{:.1}", ev_kd.spec_accept_pct),
                 format!("{:.1}", ev_kd.agree_pct), format!("{:.0}", tr_kd.tokens_per_sec)],
            vec!["CE".into(), format!("{:.3}", ev_ce.lm_loss),
                 format!("{:.1}", ev_ce.ece_pct), format!("{:.1}", ev_ce.spec_accept_pct),
                 format!("{:.1}", ev_ce.agree_pct), format!("{:.0}", tr_ce.tokens_per_sec)],
        ],
    );
    let es = pipe.engine.stats();
    report.line(format!(
        "engine: {} graph compiles ({:.1}s), {} executions ({:.1}s exec, {:.1}s transfer)",
        es.compiles, es.compile_time.as_secs_f64(), es.executions,
        es.execute_time.as_secs_f64(), es.transfer_time.as_secs_f64()
    ));
    report.finish();
    Ok(())
}
