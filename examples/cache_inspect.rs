//! Cache format tour (paper Appendix D.1): build a small cache under each
//! probability codec, inspect storage cost and quantization error, and show
//! the byte-level slot layout.
//!
//! ```sh
//! cargo run --release --example cache_inspect
//! ```

use anyhow::Result;
use rskd::cache::quant::{self, ProbCodec};
use rskd::cache::{CacheReader, CacheWriter, SparseTarget};
use rskd::report::Report;
use rskd::sampling::{random_sampling, topk};
use rskd::sampling::zipf::zipf;
use rskd::util::rng::Pcg;

fn main() -> Result<()> {
    let mut report = Report::new("cache_inspect", "Sparse-logit cache internals (Appendix D.1)");

    report.line("--- slot layout: 24 bits = 17-bit token id + 7-bit probability ---");
    let slot = quant::pack_slot(99_999, 77);
    report.line(format!("pack(id=99999, code=77) -> bytes {slot:?} -> {:?}", quant::unpack_slot(slot)));

    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(0);
    let t_topk = topk(&p, 32, false);
    let t_rs = random_sampling(&p, 50, 1.0, &mut rng);

    report.line("--- quantization error per codec (L1 of decode vs original) ---");
    let mut rows = Vec::new();
    for (name, target, codec) in [
        ("Top-32 / interval", &t_topk, ProbCodec::Interval),
        ("Top-32 / ratio (sorted)", &t_topk, ProbCodec::Ratio),
        ("RS-50 / count (exact)", &t_rs, ProbCodec::Count { rounds: 50 }),
    ] {
        let err = quant::roundtrip_l1(&target.ids, &target.probs, codec);
        rows.push(vec![name.to_string(), format!("{} slots", target.k()), format!("{err:.5}")]);
    }
    report.table(&["codec", "size", "roundtrip L1"], &rows);

    report.line("--- on-disk shards via the async ring-buffer writer ---");
    let dir = std::env::temp_dir().join("rskd-cache-inspect");
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 64)?;
    let mut rng = Pcg::new(1);
    let n_positions = 2048u64;
    for pos in 0..n_positions {
        w.push(pos, random_sampling(&p, 50, 1.0, &mut rng));
    }
    let stats = w.finish()?;
    report.line(format!(
        "{} positions -> {} shards, {} bytes ({:.1} B/position, {:.2} B/slot)",
        stats.positions, stats.shards, stats.bytes,
        stats.bytes as f64 / stats.positions as f64,
        stats.bytes as f64 / stats.slots as f64
    ));
    let dense_bytes = n_positions as f64 * 512.0 * 4.0;
    report.line(format!(
        "vs dense fp32 distributions: {dense_bytes:.0} bytes -> {:.0}x compression",
        dense_bytes / stats.bytes as f64
    ));
    let r = CacheReader::open(&dir)?;
    let t = r.get(123).unwrap();
    report.line(format!("position 123 decodes to {} tokens, mass {:.3}", t.k(), t.mass()));
    let _ = std::fs::remove_dir_all(&dir);
    report.finish();
    Ok(())
}
