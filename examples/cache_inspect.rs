//! Cache format tour (paper Appendix D.1 + docs/CACHE_FORMAT.md): build a
//! small v2 cache, inspect storage cost, quantization error, the byte-level
//! slot layout, and the directory manifest that makes out-of-order shard
//! production and lazy reading possible.
//!
//! ```sh
//! cargo run --release --example cache_inspect
//! # live serving stats from a running `rskd serve` (docs/SERVING.md):
//! cargo run --release --example cache_inspect -- --stats --port 7411
//! cargo run --release --example cache_inspect -- --stats --unix /tmp/rskd.sock
//! # the unified cross-layer metrics registry (docs/OBSERVABILITY.md):
//! cargo run --release --example cache_inspect -- --metrics --port 7411
//! # per-shard I/O residency: mapped vs heap + the bytes-copied ledger
//! # (docs/CACHE_FORMAT.md §Mapped reads):
//! cargo run --release --example cache_inspect -- --io [--dir PATH] [--heap]
//! ```

use anyhow::Result;
use rskd::cache::format::CacheManifest;
use rskd::cache::quant::{self, ProbCodec};
use rskd::cache::{CacheReader, CacheWriter, RangeBlock, ShardCodec, SparseTarget};
use rskd::report::Report;
use rskd::sampling::zipf::zipf;
use rskd::sampling::{random_sampling, topk};
use rskd::serve::stats::bucket_upper_us;
use rskd::serve::{Endpoint, ServeClient};
use rskd::spec::CachePlan;
use rskd::util::cli::Args;
use rskd::util::rng::Pcg;

/// `--stats`: connect to a running server and pretty-print its advertised
/// manifest, hot-shard counters, and the latency histogram with p50/p99.
fn stats_mode(args: &Args) -> Result<()> {
    let endpoint = Endpoint::from_cli(args.get("unix"), args.usize_or("port", 7411) as u16);
    let mut client = ServeClient::connect(&endpoint)?;
    let m = client.manifest()?;
    let s = client.stats()?;
    let mut report = Report::new("cache_inspect_stats", "Live sparse-logit server stats");
    report.line(format!(
        "server {endpoint} | cache v{} | kind {} | {} positions, {} shards, {} bytes",
        m.cache_version,
        m.kind.as_deref().unwrap_or("<untagged>"),
        m.positions,
        m.shard_count,
        m.bytes
    ));
    report.line(format!(
        "requests {} | rejected {} | errors {} | shard loads {} ({} coalesced in flight)",
        s.requests, s.rejected, s.errors, s.shard_loads, s.coalesced
    ));
    if s.tier != rskd::cache::TierCounters::default() {
        report.line(format!(
            "tier: {} hits / {} misses | {} positions backfilled | {} origin computes \
             (write-through stack — docs/SERVING.md §Miss path)",
            s.tier.hits, s.tier.misses, s.tier.backfilled, s.tier.origin_computes
        ));
    }

    report.line("--- latency histogram (log2 µs buckets) ---");
    let max = s.hist.iter().copied().max().unwrap_or(0);
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (i, &count) in s.hist.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let bar = "#".repeat(((count as f64 / max as f64) * 40.0).ceil() as usize);
        let lo = if i == 0 { 0 } else { bucket_upper_us(i - 1) };
        rows.push(vec![format!("[{lo}, {}) µs", bucket_upper_us(i)), count.to_string(), bar]);
    }
    if rows.is_empty() {
        report.line("(no range requests recorded yet)");
    } else {
        report.table(&["latency", "count", ""], &rows);
        report.line(format!(
            "p50 {} µs | p99 {} µs (upper bucket edges)",
            s.p50_us().unwrap_or(0),
            s.p99_us().unwrap_or(0)
        ));
    }

    let hot = s.hot_shards(10);
    if !hot.is_empty() {
        report.line("--- hot shards (requests overlapping each shard) ---");
        let rows: Vec<Vec<String>> =
            hot.iter().map(|(i, n)| vec![format!("shard {i}"), n.to_string()]).collect();
        report.table(&["shard", "hits"], &rows);
    }
    report.finish();
    Ok(())
}

/// `--metrics`: fetch the remote process's unified registry (`GetMetrics`,
/// docs/OBSERVABILITY.md) and render every series — the cross-layer view
/// (serve + cache tier + cluster + trainer) that the per-snapshot `--stats`
/// screen cannot show. Histogram buckets are summarized to quantiles; the
/// raw cumulative buckets are one `rskd metrics` away.
fn metrics_mode(args: &Args) -> Result<()> {
    let endpoint = Endpoint::from_cli(args.get("unix"), args.usize_or("port", 7411) as u16);
    let mut client = ServeClient::connect(&endpoint)?;
    let text = client.metrics()?;
    let parsed = rskd::obs::parse_prometheus(&text)
        .map_err(|e| anyhow::anyhow!("unparseable metrics exposition: {e}"))?;
    let snap = rskd::obs::Snapshot::from_prometheus(&text)
        .map_err(|e| anyhow::anyhow!("unparseable metrics exposition: {e}"))?;
    let mut report = Report::new("cache_inspect_metrics", "Unified metrics registry snapshot");
    report.line(format!("server {endpoint} | {} exposition lines parsed", parsed.len()));
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in &snap.series {
        let labels = s
            .labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",");
        let value = match &s.data {
            rskd::obs::SeriesData::Num(v) => v.to_string(),
            rskd::obs::SeriesData::Buckets(b) => {
                let total: u64 = b.iter().sum();
                format!(
                    "{} obs, p50 {} µs, p99 {} µs",
                    total,
                    rskd::obs::hist_quantile_us(b, 0.50).unwrap_or(0),
                    rskd::obs::hist_quantile_us(b, 0.99).unwrap_or(0)
                )
            }
        };
        rows.push(vec![s.name.clone(), labels, value]);
    }
    report.table(&["series", "labels", "value"], &rows);
    report.finish();
    Ok(())
}

/// `--io`: per-shard residency view of the zero-copy read path
/// (docs/CACHE_FORMAT.md §Mapped reads). Opens a cache directory (`--dir`,
/// or a freshly built demo cache), touches every shard once under the
/// requested I/O mode (`--heap` forces the portable fallback), and prints
/// which resident shards are mmap-backed vs heap-decoded, what they charge
/// against the reader's byte budget, and the process-wide bytes-copied /
/// bytes-mapped ledger the read path fed while doing it.
fn io_mode_view(args: &Args) -> Result<()> {
    use rskd::cache::{IoMode, ReadOptions};
    let mut report = Report::new("cache_inspect_io", "Shard I/O residency (mapped vs heap)");
    let (dir, ephemeral) = match args.get("dir") {
        Some(d) => (std::path::PathBuf::from(d), false),
        None => {
            let dir = std::env::temp_dir().join("rskd-cache-inspect-io");
            let _ = std::fs::remove_dir_all(&dir);
            let p = zipf(512, 1.0);
            let mut rng = Pcg::new(3);
            let w = CacheWriter::create(&dir, ProbCodec::Count { rounds: 50 }, 512, 64)?;
            for pos in 0..1024u64 {
                assert!(w.push(pos, random_sampling(&p, 50, 1.0, &mut rng)));
            }
            w.finish()?;
            report.line("(no --dir given: built a 1024-position demo cache)");
            (dir, true)
        }
    };
    let io = if args.bool_or("heap", false) { IoMode::Heap } else { IoMode::auto() };
    let r = CacheReader::open_with(&dir, ReadOptions { io, ..ReadOptions::default() })?;
    report.line(format!(
        "opened {} | requested {:?}, running as {:?} | {} shards",
        dir.display(),
        io,
        r.io_mode(),
        r.shard_count()
    ));

    // touch every shard once so the residency table has something to show
    // (later touches may evict earlier shards — that is the point: the table
    // below is the LRU's live view, not the manifest)
    let mut block = RangeBlock::new();
    for e in r.entries().to_vec() {
        r.read_range_into(e.start, e.count.min(64) as usize, &mut block)?;
    }

    let rows: Vec<Vec<String>> = r
        .entries()
        .iter()
        .zip(r.shard_io())
        .map(|(e, io)| {
            let file = e
                .path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_else(|| e.path.display().to_string());
            let (state, bytes) = match io {
                Some((true, b)) => ("mapped".to_string(), format!("{b} B")),
                Some((false, b)) => ("heap".to_string(), format!("{b} B")),
                None => ("cold".to_string(), "-".to_string()),
            };
            vec![file, format!("[{}, {})", e.start, e.start + e.count), state, bytes]
        })
        .collect();
    report.table(&["shard file", "position range", "I/O", "resident"], &rows);
    report.line(format!(
        "resident: {} shard(s), {} bytes charged against the byte budget",
        r.resident_shards(),
        r.resident_bytes()
    ));

    // the process-wide ledger: what this process's reads copied through
    // intermediate buffers vs served straight from mappings
    let reg = rskd::obs::registry();
    report.line(format!(
        "ledger: {} bytes copied, {} bytes mapped (rskd_io_bytes_copied_total / \
         rskd_io_bytes_mapped_total)",
        reg.counter("rskd_io_bytes_copied_total", &[]).get(),
        reg.counter("rskd_io_bytes_mapped_total", &[]).get()
    ));
    if ephemeral {
        drop(r);
        let _ = std::fs::remove_dir_all(&dir);
    }
    report.finish();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.bool_or("stats", false) {
        return stats_mode(&args);
    }
    if args.bool_or("metrics", false) {
        return metrics_mode(&args);
    }
    if args.bool_or("io", false) {
        return io_mode_view(&args);
    }
    let mut report = Report::new("cache_inspect", "Sparse-logit cache internals (Appendix D.1)");

    report.line("--- slot layout: 24 bits = 17-bit token id + 7-bit probability ---");
    let slot = quant::pack_slot(99_999, 77);
    report.line(format!("pack(id=99999, code=77) -> bytes {slot:?} -> {:?}", quant::unpack_slot(slot)));

    let p = zipf(512, 1.0);
    let mut rng = Pcg::new(0);
    let t_topk = topk(&p, 32);
    let t_rs = random_sampling(&p, 50, 1.0, &mut rng);

    report.line("--- quantization error per codec (L1 of decode vs original) ---");
    let mut rows = Vec::new();
    for (name, target, codec) in [
        ("Top-32 / interval", &t_topk, ProbCodec::Interval),
        ("Top-32 / ratio (sorted)", &t_topk, ProbCodec::Ratio),
        ("RS-50 / count (exact)", &t_rs, ProbCodec::Count { rounds: 50 }),
    ] {
        let err = quant::roundtrip_l1(&target.ids, &target.probs, codec);
        rows.push(vec![name.to_string(), format!("{} slots", target.k()), format!("{err:.5}")]);
    }
    report.table(&["codec", "size", "roundtrip L1"], &rows);

    report.line("--- on-disk v2 shards via the out-of-order ring-buffer writer ---");
    let dir = std::env::temp_dir().join("rskd-cache-inspect");
    let _ = std::fs::remove_dir_all(&dir);
    let w = CacheWriter::create_with_kind(
        &dir,
        ProbCodec::Count { rounds: 50 },
        512,
        64,
        Some("rs:rounds=50,temp=1".into()),
    )?;
    let n_positions = 2048u64;
    // push in reverse to show that producer order no longer matters
    let mut rng = Pcg::new(1);
    let targets: Vec<SparseTarget> =
        (0..n_positions).map(|_| random_sampling(&p, 50, 1.0, &mut rng)).collect();
    for pos in (0..n_positions).rev() {
        assert!(w.push(pos, targets[pos as usize].clone()));
    }
    let stats = w.finish()?;
    report.line(format!(
        "{} positions (pushed in reverse) -> {} shards, {} bytes ({:.1} B/position, {:.2} B/slot)",
        stats.positions, stats.shards, stats.bytes,
        stats.bytes as f64 / stats.positions as f64,
        stats.bytes as f64 / stats.slots as f64
    ));
    let dense_bytes = n_positions as f64 * 512.0 * 4.0;
    report.line(format!(
        "vs dense fp32 distributions: {dense_bytes:.0} bytes -> {:.0}x compression",
        dense_bytes / stats.bytes as f64
    ));

    report.line("--- index.json manifest (v2 shard directory) ---");
    let manifest = CacheManifest::load(&dir)?;
    report.line(format!(
        "version {} | codec tag {} (rounds {}) | shard codec {} | kind {} | \
         {} positions, {} slots, {} bytes",
        manifest.version,
        manifest.codec.tag(),
        manifest.rounds(),
        manifest.shard_codec,
        manifest.kind.as_deref().unwrap_or("<untagged>"),
        manifest.positions,
        manifest.slots,
        manifest.bytes
    ));
    let rows: Vec<Vec<String>> = manifest
        .shards
        .iter()
        .map(|s| {
            vec![
                s.file.clone(),
                format!("[{}, {})", s.start, s.start + s.count),
                format!("{} B", s.bytes),
            ]
        })
        .collect();
    report.table(&["shard file", "position range", "size"], &rows);

    report.line("--- byte-level shard codecs (v3; docs/CACHE_FORMAT.md §Codec) ---");
    let raw_bytes = stats.bytes;
    let mut rows = vec![vec![
        "raw (v2)".to_string(),
        format!("{raw_bytes} B"),
        format!("{:.2}", raw_bytes as f64 / stats.slots as f64),
        "1.00x".to_string(),
    ]];
    let mut raw_block = RangeBlock::new();
    CacheReader::open(&dir)?.read_range_into(0, n_positions as usize, &mut raw_block)?;
    for sc in [ShardCodec::Delta, ShardCodec::DeltaPacked, ShardCodec::DeltaPackedLz] {
        let cdir = std::env::temp_dir().join(format!("rskd-cache-inspect-{sc}"));
        let _ = std::fs::remove_dir_all(&cdir);
        let w = CacheWriter::create_coded(
            &cdir,
            ProbCodec::Count { rounds: 50 },
            sc,
            512,
            64,
            Some("rs:rounds=50,temp=1".into()),
        )?;
        for pos in 0..n_positions {
            assert!(w.push(pos, targets[pos as usize].clone()));
        }
        let cstats = w.finish()?;
        // same records, smaller files — and bit-identical decoded blocks
        let cr = CacheReader::open(&cdir)?;
        let mut block = RangeBlock::new();
        cr.read_range_into(0, n_positions as usize, &mut block)?;
        assert_eq!(block, raw_block, "{sc} decode must be bit-identical to raw");
        rows.push(vec![
            format!("{sc} (v3)"),
            format!("{} B", cstats.bytes),
            format!("{:.2}", cstats.bytes as f64 / cstats.slots as f64),
            format!("{:.2}x", raw_bytes as f64 / cstats.bytes as f64),
        ]);
        let _ = std::fs::remove_dir_all(&cdir);
    }
    report.table(&["shard codec", "bytes", "B/slot", "ratio vs raw"], &rows);
    report.line("decoded RangeBlocks verified bit-identical across all codecs");

    report.line("--- inferred cache plan (spec-layer view of this directory) ---");
    let r = CacheReader::open(&dir)?;
    match r.cache_kind() {
        Ok(kind) => {
            let plan = CachePlan::prebuilt(kind);
            report.line(format!(
                "kind {kind} -> plan {plan}, registry tag `{}`; serves specs whose \
                 cache_plan() matches (see docs/SPEC.md compatibility matrix)",
                plan.dir_tag()
            ));
        }
        Err(e) => report.line(format!("kind unparseable ({e}): training would refuse this cache")),
    }

    report.line("--- lazy LRU reader ---");
    report.line(format!(
        "open: {} shards indexed, {} decoded (metadata only)",
        r.shard_count(),
        r.resident_shards()
    ));
    let t = r.get(123).unwrap();
    report.line(format!(
        "position 123 decodes to {} tokens, mass {:.3}; now {} shard resident, {} load(s)",
        t.k(),
        t.mass(),
        r.resident_shards(),
        r.shard_loads()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    report.finish();
    Ok(())
}
