//! Speculative decoding demo (paper §5 metric): train a small student
//! quickly with RS-KD, then simulate the draft-verify loop against the
//! teacher and compare with the analytic acceptance rate.
//!
//! ```sh
//! make artifacts && cargo run --release --example speculative_decoding
//! ```

use anyhow::Result;
use rskd::coordinator::{Pipeline, PipelineConfig};
use rskd::report::Report;
use rskd::runtime::HostTensor;
use rskd::spec::DistillSpec;
use rskd::specdecode::{analytic_accept, simulate};
use rskd::util::rng::Pcg;

fn main() -> Result<()> {
    let cfg = PipelineConfig {
        target_tokens: 100_000,
        teacher_steps: 150,
        student_steps: 100,
        eval_batches: 3,
        work_dir: "target/specdemo".into(),
        ..Default::default()
    };
    let mut pipe = Pipeline::prepare(cfg)?;
    let m = pipe.engine.manifest();
    let (b, s, v) = (m.batch, m.seq, m.vocab);

    let (student, _, _) = pipe.run_spec(&DistillSpec::rs(50), 3)?;
    let (student_ce, _, _) = pipe.run_spec(&DistillSpec::ce(), 3)?;

    // gather aligned draft/target prob rows on an eval batch
    let batch = pipe.eval_loader().next_batch_for_demo();
    let toks = HostTensor::i32(batch.0, &[b, s]);
    let t_rows = rows_of(&pipe, &pipe.teacher, &toks, v)?;

    let mut report = Report::new("speculative_decoding", "Draft-verify simulation (paper §5 metric)");
    let mut rows = Vec::new();
    for (name, model) in [("RS-KD student", &student), ("CE student", &student_ce)] {
        let d_rows = rows_of(&pipe, model, &toks, v)?;
        let analytic: f64 = d_rows
            .iter()
            .zip(t_rows.iter())
            .map(|(d, t)| analytic_accept(d, t))
            .sum::<f64>()
            / d_rows.len() as f64;
        let mut rng = Pcg::new(7);
        let sim = simulate(&d_rows, &t_rows, 4, &mut rng);
        rows.push(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * analytic),
            format!("{:.1}%", 100.0 * sim.accept_rate()),
            format!("{:.2}", sim.tokens_per_verify),
        ]);
    }
    report.table(&["draft model", "analytic accept", "simulated accept", "tokens/verify"], &rows);
    report.finish();
    Ok(())
}

fn rows_of(
    pipe: &Pipeline,
    model: &rskd::model::ModelState,
    toks: &HostTensor,
    v: usize,
) -> Result<Vec<Vec<f32>>> {
    let probs = pipe
        .engine
        .call(&format!("fwd_{}", model.role), &[model.params_tensor(), toks.clone()])?
        .remove(0);
    Ok(probs.as_f32()?.chunks(v).map(|c| c.to_vec()).collect())
}

trait DemoLoader {
    fn next_batch_for_demo(&self) -> (Vec<i32>, Vec<i32>);
}

impl DemoLoader for rskd::data::loader::Loader {
    fn next_batch_for_demo(&self) -> (Vec<i32>, Vec<i32>) {
        let b = self.iter_eval().next().expect("eval loader empty");
        (b.tokens, b.labels)
    }
}
