//! Calibration study (paper §2.2/§4.1 in miniature): why Top-K KD produces
//! over-confident students and RS-KD does not, shown on the standalone toy
//! MLP (no PJRT needed — runs anywhere).
//!
//! ```sh
//! cargo run --release --example calibration_study
//! ```

use rskd::report::Report;
use rskd::sampling::estimator::estimator_stats;
use rskd::sampling::zipf::zipf;
use rskd::spec::{DistillSpec, Variant};
use rskd::toynn::train::train_teacher;
use rskd::toynn::{train_toy, GaussianClasses, ToyMethod, ToyTrainConfig};

fn main() {
    let mut report = Report::new("calibration_study", "Why Top-K mis-calibrates and RS-KD does not");

    report.line("--- estimator view: bias/variance on a Zipf teacher row ---");
    let p = zipf(512, 1.0);
    let mut rows = Vec::new();
    for spec in [
        DistillSpec::sparse(Variant::TopK { k: 12, normalize: true }),
        DistillSpec::sparse(Variant::NaiveFix { k: 12 }),
        DistillSpec::rs(12),
        DistillSpec::rs(50),
        DistillSpec::sparse(Variant::Rs { rounds: 50, temp: 0.25 }),
    ] {
        let st = estimator_stats(&p, &spec, 500, 0);
        rows.push(vec![
            spec.name(),
            format!("{:.4}", st.bias_l1),
            format!("{:.4}", st.mean_l1),
            format!("{:.5}", st.variance),
            format!("{:.1}", st.avg_slots),
        ]);
    }
    report.table(&["estimator", "bias L1", "per-draw L1", "variance", "slots"], &rows);

    report.line("--- student view: toy MLP trained from each target ---");
    let data = GaussianClasses::new(128, 64, 1.5, 0);
    let cfg = ToyTrainConfig { steps: 600, ..Default::default() };
    let teacher = train_teacher(|b, r| data.batch(b, r), 64, 128, &cfg);
    let mut rows = Vec::new();
    for m in [
        ToyMethod::Ce,
        ToyMethod::FullKd,
        ToyMethod::TopK { k: 7 },
        ToyMethod::RandomSampling { rounds: 50 },
    ] {
        let res = train_toy(|b, r| data.batch(b, r), 64, 128, Some(&teacher), m, &cfg);
        rows.push(vec![
            m.name().to_string(),
            format!("{:.1}", res.accuracy * 100.0),
            format!("{:.2}", res.calibration.mean_conf),
            format!("{:.1}", res.calibration.ece * 100.0),
        ]);
    }
    report.table(&["method", "accuracy %", "mean confidence", "ECE %"], &rows);
    report.line("Top-K's scaled-up targets (grad = Σt·p − t, paper Eq. 2) inflate confidence;");
    report.line("the unbiased RS estimator preserves the FullKD gradient in expectation (App. A.6).");
    report.finish();
}
