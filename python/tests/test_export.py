"""Export smoke tests: manifest consistency + HLO text sanity.

Runs against artifacts/small if present (`make artifacts`); otherwise exports
a throwaway config into a temp dir.
"""

import json
import os

import jax
import pytest

from compile import aot, model
from compile.configs import CONFIGS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "small")


@pytest.fixture(scope="module")
def manifest_dir(tmp_path_factory):
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return ART
    out = tmp_path_factory.mktemp("artifacts")
    aot.export_config("small", str(out))
    return os.path.join(str(out), "small")


@pytest.fixture(scope="module")
def manifest(manifest_dir):
    with open(os.path.join(manifest_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_core_graphs(manifest):
    names = set(manifest["graphs"])
    for must in [
        "init_teacher", "init_student", "fwd_teacher", "fwd_student",
        "train_ce_student", "train_ce_teacher", "train_dense_student",
        "train_sparse_student", "train_sparse_jnp_student",
        "grad_ce_student", "grad_dense_student", "grad_sparse_student",
        "eval_student", "eval_teacher", "agree_student",
        "sample_rs", "sample_topk",
        "train_dense_rkl_student", "train_dense_mse_student",
        "train_dense_l1_student", "train_dense_frkl_student",
    ]:
        assert must in names, must


def test_files_exist_and_parse(manifest, manifest_dir):
    for name, g in manifest["graphs"].items():
        path = os.path.join(manifest_dir, g["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "ENTRY" in text and "ROOT" in text, name


def test_param_counts_match_model(manifest):
    cfg = CONFIGS["small"]
    roles = {"teacher": cfg.teacher, **cfg.students}
    for role, dims in roles.items():
        assert manifest["roles"][role]["param_count"] == model.param_count(dims)


def test_graph_arg_shapes(manifest):
    cfg = CONFIGS["small"]
    b, s, v = cfg.batch, cfg.seq, cfg.vocab
    g = manifest["graphs"]["train_sparse_student"]
    p = manifest["roles"]["student"]["param_count"]
    shapes = [tuple(a["shape"]) for a in g["args"]]
    assert shapes[0] == (p,) and shapes[1] == (p,) and shapes[2] == (p,)
    assert shapes[5] == (b, s) and shapes[7] == (b, s, cfg.k_slots)
    outs = [tuple(o["shape"]) for o in g["outputs"]]
    assert outs[0] == (p,) and outs[4] == ()


def test_sampler_graph_shapes(manifest):
    cfg = CONFIGS["small"]
    g = manifest["graphs"]["sample_rs"]
    assert tuple(g["args"][0]["shape"]) == (cfg.batch, cfg.seq, cfg.vocab)
    assert tuple(g["outputs"][0]["shape"]) == (cfg.batch, cfg.seq, cfg.n_rounds)
    assert g["outputs"][0]["dtype"] == "i32"


def test_dtypes_are_declared(manifest):
    for name, g in manifest["graphs"].items():
        for a in g["args"] + g["outputs"]:
            assert a["dtype"] in ("f32", "i32"), name
