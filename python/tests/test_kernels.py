"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes/temperatures/knobs; statistical tests verify the
paper's core claims at the estimator level: Random Sampling is unbiased
(§3.4 / Appendix A.6), Top-K is biased (§2.2.1).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import assume, given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sampler import sample_rs
from compile.kernels.sparse_kld import sparse_kld

RNG = np.random.default_rng(1234)


def _mk_sparse(r, v, k, seed=0, mass=0.8):
    rng = np.random.default_rng(seed)
    logits = jnp.array(rng.normal(size=(r, v)), jnp.float32)
    idx = jnp.array(rng.integers(0, v, size=(r, k)), jnp.int32)
    raw = rng.random(size=(r, k)).astype(np.float32)
    val = jnp.array(mass * raw / raw.sum(-1, keepdims=True), jnp.float32)
    return logits, idx, val


shape_strat = st.tuples(
    st.sampled_from([1, 2, 3, 8, 16]),  # rows
    st.sampled_from([8, 32, 64, 200]),  # vocab
    st.sampled_from([1, 4, 8, 16]),  # slots
)


class TestSparseKld:
    @settings(max_examples=25, deadline=None)
    @given(shape=shape_strat, smooth=st.sampled_from([0.0, 1e-4, 1e-3]),
           ghost=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 10_000))
    def test_fwd_matches_ref(self, shape, smooth, ghost, seed):
        r, v, k = shape
        # ghost token is only meaningful with a non-trivial residual: when the
        # support can cover the whole vocab, 1-s_p degenerates (see DESIGN.md)
        assume(not (ghost > 0 and 2 * k >= v))
        logits, idx, val = _mk_sparse(r, v, k, seed)
        sm = jnp.full((r,), smooth, jnp.float32)
        gh = jnp.full((r,), ghost, jnp.float32)
        w = jnp.array(RNG.random(r) + 0.5, jnp.float32)
        got = sparse_kld(logits, idx, val, sm, gh, w)
        want = ref.sparse_kld_ref(logits, idx, val, sm, gh, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(shape=shape_strat, smooth=st.sampled_from([0.0, 1e-4]),
           ghost=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 10_000))
    def test_bwd_matches_ref(self, shape, smooth, ghost, seed):
        r, v, k = shape
        assume(not (ghost > 0 and 2 * k >= v))
        logits, idx, val = _mk_sparse(r, v, k, seed)
        sm = jnp.full((r,), smooth, jnp.float32)
        gh = jnp.full((r,), ghost, jnp.float32)
        w = jnp.ones((r,), jnp.float32)
        got = jax.grad(lambda x: jnp.sum(sparse_kld(x, idx, val, sm, gh, w)))(logits)
        want = ref.sparse_kld_grad_ref(logits, idx, val, sm, gh, w, jnp.ones((r,)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)

    def test_manual_bwd_matches_autodiff_of_ref(self):
        """The paper's closed-form gradient (A.4/A.5) == autodiff of the loss."""
        r, v, k = 8, 64, 8
        logits, idx, val = _mk_sparse(r, v, k, seed=7)
        for ghost in (0.0, 1.0):
            for smooth in (0.0, 1e-4):
                sm = jnp.full((r,), smooth, jnp.float32)
                gh = jnp.full((r,), ghost, jnp.float32)
                w = jnp.ones((r,), jnp.float32)
                manual = jax.grad(
                    lambda x: jnp.sum(sparse_kld(x, idx, val, sm, gh, w)))(logits)
                auto = jax.grad(
                    lambda x: jnp.sum(ref.sparse_kld_ref(x, idx, val, sm, gh, w)))(logits)
                np.testing.assert_allclose(manual, auto, rtol=1e-4, atol=1e-5)

    def test_fullkd_gradient_identity(self):
        """With the complete distribution as target, grad = p - t (Eq. 1)."""
        r, v = 4, 32
        rng = np.random.default_rng(3)
        logits = jnp.array(rng.normal(size=(r, v)), jnp.float32)
        t = jax.nn.softmax(jnp.array(rng.normal(size=(r, v)), jnp.float32))
        idx = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), (r, v))
        zeros = jnp.zeros((r,), jnp.float32)
        ones = jnp.ones((r,), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(sparse_kld(x, idx, t, zeros, zeros, ones)))(logits)
        p = jax.nn.softmax(logits)
        np.testing.assert_allclose(g, p - t, rtol=1e-5, atol=1e-6)

    def test_topk_gradient_is_scaled(self):
        """Vanilla Top-K target: grad = (sum_K t) * p - t (paper Eq. 2)."""
        r, v, k = 4, 32, 5
        logits, idx, val = _mk_sparse(r, v, k, seed=11, mass=0.6)
        zeros = jnp.zeros((r,), jnp.float32)
        ones = jnp.ones((r,), jnp.float32)
        g = jax.grad(lambda x: jnp.sum(sparse_kld(x, idx, val, zeros, zeros, ones)))(logits)
        p = jax.nn.softmax(logits)
        t = ref.scatter_targets(idx, val, v)
        sum_t = jnp.sum(t, -1, keepdims=True)
        np.testing.assert_allclose(g, sum_t * p - t, rtol=1e-5, atol=1e-6)

    def test_duplicate_ids_merge(self):
        r, v = 2, 16
        logits = jnp.array(RNG.normal(size=(r, v)), jnp.float32)
        idx_dup = jnp.array([[3, 3, 5, 0], [1, 1, 1, 2]], jnp.int32)
        val = jnp.array([[0.1, 0.2, 0.3, 0.0], [0.1, 0.1, 0.1, 0.4]], jnp.float32)
        zeros = jnp.zeros((r,), jnp.float32)
        ones = jnp.ones((r,), jnp.float32)
        merged_idx = jnp.array([[3, 5, 0, 0], [1, 2, 0, 0]], jnp.int32)
        merged_val = jnp.array([[0.3, 0.3, 0.0, 0.0], [0.3, 0.4, 0.0, 0.0]], jnp.float32)
        a = sparse_kld(logits, idx_dup, val, zeros, zeros, ones)
        b = sparse_kld(logits, merged_idx, merged_val, zeros, zeros, ones)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_weight_scales_loss_and_grad(self):
        r, v, k = 4, 32, 4
        logits, idx, val = _mk_sparse(r, v, k, seed=5)
        zeros = jnp.zeros((r,), jnp.float32)
        w1 = jnp.ones((r,), jnp.float32)
        w2 = jnp.full((r,), 2.0, jnp.float32)
        np.testing.assert_allclose(
            sparse_kld(logits, idx, val, zeros, zeros, w2),
            2.0 * sparse_kld(logits, idx, val, zeros, zeros, w1), rtol=1e-6)


class TestSampler:
    @settings(max_examples=20, deadline=None)
    @given(r=st.sampled_from([1, 4, 16]), v=st.sampled_from([16, 64, 200]),
           n=st.sampled_from([1, 8, 50]),
           temp=st.sampled_from([0.5, 0.8, 1.0, 1.2, 2.0]),
           seed=st.integers(0, 10_000))
    def test_matches_ref(self, r, v, n, temp, seed):
        rng = np.random.default_rng(seed)
        probs = jax.nn.softmax(jnp.array(rng.normal(size=(r, v)) * 2, jnp.float32))
        unif = jnp.array(rng.random(size=(r, n)), jnp.float32)
        t = jnp.full((r,), temp, jnp.float32)
        ids_k, w_k = sample_rs(probs, unif, t)
        ids_r, w_r = ref.sample_rs_ref(probs, unif, t)
        np.testing.assert_array_equal(ids_k, ids_r)
        np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-7)

    def test_weights_sum_to_one(self):
        rng = np.random.default_rng(0)
        probs = jax.nn.softmax(jnp.array(rng.normal(size=(8, 128)) * 3, jnp.float32))
        unif = jnp.array(rng.random(size=(8, 50)), jnp.float32)
        _, w = sample_rs(probs, unif, jnp.ones((8,), jnp.float32))
        np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-5)

    def test_temp_one_gives_uniform_weights(self):
        """q = p at t=1 so every draw has ratio 1: weights = 1/N exactly
        (the paper's counts/N pseudocode)."""
        rng = np.random.default_rng(1)
        probs = jax.nn.softmax(jnp.array(rng.normal(size=(4, 64)), jnp.float32))
        unif = jnp.array(rng.random(size=(4, 10)), jnp.float32)
        _, w = sample_rs(probs, unif, jnp.ones((4,), jnp.float32))
        np.testing.assert_allclose(np.asarray(w), 0.1, rtol=1e-5)

    def test_rs_estimator_is_unbiased(self):
        """Mean of scattered RS estimates converges to the true distribution."""
        v, n, rounds = 32, 16, 4000
        rng = np.random.default_rng(42)
        p = np.asarray(jax.nn.softmax(jnp.array(rng.normal(size=(v,)) * 2, jnp.float32)))
        probs = jnp.broadcast_to(jnp.array(p), (rounds, v))
        unif = jnp.array(rng.random(size=(rounds, n)), jnp.float32)
        ids, w = ref.sample_rs_ref(probs, unif, jnp.ones((rounds,), jnp.float32))
        dense = np.asarray(ref.scatter_targets(ids, w, v))
        est = dense.mean(0)
        assert np.abs(est - p).max() < 0.02
        assert np.abs(est - p).sum() < 0.06

    def test_topk_estimator_is_biased(self):
        """Normalized Top-K systematically overestimates head probabilities
        (paper §2.2.1); RS with matched support size does not."""
        v, k = 64, 8
        idxs = np.arange(1, v + 1)
        p = (1.0 / idxs) / (1.0 / idxs).sum()  # Zipf
        topk = np.zeros(v)
        topk[:k] = p[:k] / p[:k].sum()
        assert (topk[:k] > p[:k]).all()
        l1_topk = np.abs(topk - p).sum()
        assert l1_topk > 0.3  # substantial bias on a Zipf tail

    def test_temp_zero_is_uniform_proposal(self):
        rng = np.random.default_rng(2)
        v = 64
        probs = jax.nn.softmax(jnp.array(rng.normal(size=(1, v)) * 4, jnp.float32))
        unif = jnp.array(rng.random(size=(1, 2000)), jnp.float32)
        ids, _ = ref.sample_rs_ref(probs, unif, jnp.zeros((1,), jnp.float32))
        counts = np.bincount(np.asarray(ids)[0], minlength=v)
        # uniform proposal: every token id sampled at roughly equal frequency
        assert counts.min() > 0.3 * counts.mean()


class TestDenseLosses:
    def test_kld_zero_at_match(self):
        rng = np.random.default_rng(0)
        logits = jnp.array(rng.normal(size=(4, 32)), jnp.float32)
        t = jax.nn.softmax(logits)
        losses = ref.dense_losses_ref(logits, t, "kld")
        np.testing.assert_allclose(losses, 0.0, atol=1e-5)

    @pytest.mark.parametrize("kind", ["kld", "rkl", "frkl", "mse", "l1"])
    def test_nonnegative(self, kind):
        rng = np.random.default_rng(4)
        logits = jnp.array(rng.normal(size=(8, 32)), jnp.float32)
        t = jax.nn.softmax(jnp.array(rng.normal(size=(8, 32)), jnp.float32))
        losses = ref.dense_losses_ref(logits, t, kind)
        assert (np.asarray(losses) > -1e-5).all()
