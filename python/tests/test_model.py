"""L2 correctness: model shapes, flat-param layout, training dynamics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS, ExportConfig, ModelDims

TINY = ModelDims(vocab=64, d_model=32, n_layers=2, n_heads=2, n_kv_heads=1, d_ff=64)
TINY_CFG = ExportConfig(name="tiny", teacher=TINY, students={"student": TINY},
                        batch=2, seq=16, k_slots=8, n_rounds=8)


def _init(seed=0):
    return model.init_flat(jnp.int32(seed), TINY)


class TestLayout:
    def test_param_count_matches_configs(self):
        for cfg in CONFIGS.values():
            for dims in [cfg.teacher, *cfg.students.values()]:
                assert model.param_count(dims) == dims.param_count()

    def test_init_length(self):
        flat = _init()
        assert flat.shape == (model.param_count(TINY),)
        assert bool(jnp.all(jnp.isfinite(flat)))

    def test_unflatten_roundtrip(self):
        flat = _init()
        params = model.unflatten(flat, TINY)
        names = [n for n, _ in model.param_shapes(TINY)]
        assert list(params) == names
        re_flat = jnp.concatenate([params[n].reshape(-1) for n in names])
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(re_flat))

    def test_norms_init_to_one(self):
        params = model.unflatten(_init(), TINY)
        np.testing.assert_array_equal(np.asarray(params["l0.attn_norm"]), 1.0)
        np.testing.assert_array_equal(np.asarray(params["final_norm"]), 1.0)

    def test_different_seeds_differ(self):
        assert not np.allclose(np.asarray(_init(0)), np.asarray(_init(1)))


class TestForward:
    def test_shapes_and_normalization(self):
        flat = _init()
        toks = jnp.zeros((2, 16), jnp.int32)
        probs = model.forward_probs(flat, toks, TINY)
        assert probs.shape == (2, 16, 64)
        np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)

    def test_causality(self):
        """Changing a future token must not affect past positions."""
        flat = _init()
        rng = np.random.default_rng(0)
        toks = jnp.array(rng.integers(0, 64, size=(1, 16)), jnp.int32)
        toks2 = toks.at[0, 10].set((toks[0, 10] + 1) % 64)
        a = model.forward_logits(flat, toks, TINY)
        b = model.forward_logits(flat, toks2, TINY)
        np.testing.assert_allclose(np.asarray(a)[0, :10], np.asarray(b)[0, :10],
                                   rtol=1e-4, atol=1e-5)
        assert not np.allclose(np.asarray(a)[0, 10:], np.asarray(b)[0, 10:])


def _batch(rng, b=2, s=16, v=64):
    toks = jnp.array(rng.integers(0, v, size=(b, s)), jnp.int32)
    labels = jnp.array(rng.integers(0, v, size=(b, s)), jnp.int32)
    return toks, labels


class TestTraining:
    def test_ce_loss_decreases(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        train, _ = graphs["train_ce_student"]
        rng = np.random.default_rng(0)
        toks, labels = _batch(rng)
        flat = _init()
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        step = jnp.int32(0)
        first = None
        fn = jax.jit(train)
        for _ in range(30):
            flat, m, v, step, loss = fn(flat, m, v, step, jnp.float32(1e-2), toks, labels)
            if first is None:
                first = float(loss)
        assert float(loss) < first - 0.5

    def test_sparse_pallas_equals_jnp_graph(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        tp, _ = graphs["train_sparse_student"]
        tj, _ = graphs["train_sparse_jnp_student"]
        rng = np.random.default_rng(1)
        toks, labels = _batch(rng)
        k = TINY_CFG.k_slots
        idx = jnp.array(rng.integers(0, 64, size=(2, 16, k)), jnp.int32)
        raw = rng.random(size=(2, 16, k)).astype(np.float32)
        val = jnp.array(raw / raw.sum(-1, keepdims=True), jnp.float32)
        flat = _init()
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        args = (flat, m, v, jnp.int32(0), jnp.float32(1e-3), toks, labels, idx, val,
                jnp.float32(0.0), jnp.zeros((2, 16), jnp.float32), jnp.float32(0.0),
                jnp.ones((2, 16), jnp.float32))
        out_p = tp(*args)
        out_j = tj(*args)
        for a, b in zip(out_p, out_j):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)

    def test_fullkd_as_sparse_equals_dense(self):
        """Feeding the complete distribution through the sparse path must match
        the dense FullKD loss (sanity: sparse graph generalizes FullKD)."""
        tiny = ModelDims(vocab=16, d_model=32, n_layers=1, n_heads=2, n_kv_heads=1, d_ff=32)
        cfg = ExportConfig(name="t", teacher=tiny, students={"student": tiny},
                           batch=2, seq=4, k_slots=16, n_rounds=8)
        rng = np.random.default_rng(2)
        toks = jnp.array(rng.integers(0, 16, size=(2, 4)), jnp.int32)
        labels = jnp.array(rng.integers(0, 16, size=(2, 4)), jnp.int32)
        tprobs = jax.nn.softmax(jnp.array(rng.normal(size=(2, 4, 16)), jnp.float32))
        flat = model.init_flat(jnp.int32(0), tiny)
        idx = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 4, 16))
        _, kd_sparse = model.loss_sparse(
            flat, toks, labels, idx, tprobs, jnp.float32(0.0), jnp.zeros((2, 4), jnp.float32),
            jnp.float32(0.0), jnp.ones((2, 4), jnp.float32), tiny, cfg)
        _, kd_dense = model.loss_dense(
            flat, toks, labels, tprobs, jnp.float32(0.0), tiny, cfg, "kld")
        np.testing.assert_allclose(float(kd_sparse), float(kd_dense), rtol=1e-4)

    def test_grad_clip(self):
        g = jnp.full((10,), 100.0)
        flat = jnp.zeros((10,))
        m = jnp.zeros((10,))
        v = jnp.zeros((10,))
        _, m1, _, _ = model.adam_step(flat, m, v, jnp.int32(0), jnp.float32(1e-3), g)
        # after clipping to norm 1, m = 0.1 * g_clipped
        clipped = g / jnp.sqrt(jnp.sum(g * g))
        np.testing.assert_allclose(np.asarray(m1), 0.1 * np.asarray(clipped), rtol=1e-5)

    def test_adam_bias_correction_first_step(self):
        g = jnp.full((4,), 0.5)
        flat = jnp.zeros((4,))
        out, _, _, step1 = model.adam_step(flat, jnp.zeros((4,)), jnp.zeros((4,)),
                                           jnp.int32(0), jnp.float32(1e-3), g)
        assert int(step1) == 1
        # bias-corrected first step is ~ -lr * sign(g)
        np.testing.assert_allclose(np.asarray(out), -1e-3, rtol=1e-3)


class TestEvalGraphs:
    def test_eval_outputs(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        ev, _ = graphs["eval_student"]
        rng = np.random.default_rng(3)
        toks, labels = _batch(rng)
        loss_sum, conf, correct, label_prob = ev(_init(), toks, labels)
        assert conf.shape == (2, 16)
        c = np.asarray(conf)
        lp = np.asarray(label_prob)
        assert (c >= lp - 1e-6).all()  # max prob >= prob of the label
        assert ((np.asarray(correct) == 0) | (np.asarray(correct) == 1)).all()
        assert float(loss_sum) > 0

    def test_agree_bounds(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        ag, _ = graphs["agree_student"]
        rng = np.random.default_rng(4)
        toks, _ = _batch(rng)
        tprobs = jax.nn.softmax(jnp.array(rng.normal(size=(2, 16, 64)), jnp.float32))
        accept, agree = ag(_init(), toks, tprobs)
        a = np.asarray(accept)
        assert (a > 0).all() and (a <= 1 + 1e-5).all()

    def test_agree_with_self_is_one(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        ag, _ = graphs["agree_student"]
        rng = np.random.default_rng(5)
        toks, _ = _batch(rng)
        flat = _init()
        tprobs = model.forward_probs(flat, toks, TINY)
        accept, agree = ag(flat, toks, tprobs)
        np.testing.assert_allclose(np.asarray(accept), 1.0, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(agree), 1.0)

    def test_next_probs_matches_fwd(self):
        graphs = model.make_graphs(TINY_CFG, "student", TINY)
        npf, _ = graphs["next_probs_student"]
        rng = np.random.default_rng(6)
        toks, _ = _batch(rng)
        flat = _init()
        probs = model.forward_probs(flat, toks, TINY)
        out = npf(flat, toks, jnp.int32(5))[0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(probs)[:, 5, :], rtol=1e-5)
