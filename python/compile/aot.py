"""AOT export: lower every graph to HLO *text* + write manifest.json.

HLO text (NOT `lowered.compile()` / proto `.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --config small --out ../artifacts
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds_json(sds):
    dt = str(sds.dtype)
    return {"shape": list(sds.shape), "dtype": {"float32": "f32", "int32": "i32"}[dt]}


def export_config(cfg_name: str, out_root: str) -> None:
    cfg = CONFIGS[cfg_name]
    out_dir = os.path.join(out_root, cfg_name)
    os.makedirs(out_dir, exist_ok=True)

    all_graphs = {}
    roles = {"teacher": cfg.teacher, **cfg.students}
    for role, dims in roles.items():
        all_graphs.update(model.make_graphs(cfg, role, dims))
    all_graphs.update(model.make_sampler_graphs(cfg))

    manifest = {
        "config": cfg_name,
        "batch": cfg.batch,
        "seq": cfg.seq,
        "vocab": cfg.vocab,
        "k_slots": cfg.k_slots,
        "n_rounds": cfg.n_rounds,
        "roles": {
            role: {
                "d_model": dims.d_model,
                "n_layers": dims.n_layers,
                "n_heads": dims.n_heads,
                "n_kv_heads": dims.n_kv_heads,
                "d_ff": dims.d_ff,
                "param_count": model.param_count(dims),
            }
            for role, dims in roles.items()
        },
        "graphs": {},
    }

    for name, (fn, args) in sorted(all_graphs.items()):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        manifest["graphs"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [_sds_json(a) for a in args],
            "outputs": [_sds_json(o) for o in outs],
        }
        print(f"  {name}: {len(text)} chars ({time.time() - t0:.1f}s)", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json ({len(all_graphs)} graphs)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="small", choices=sorted(CONFIGS))
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    export_config(args.config, args.out)


if __name__ == "__main__":
    main()
