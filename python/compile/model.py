"""L2: LLaMA-style transformer + training/eval graphs in JAX.

Every exported graph works on a *flat f32 parameter vector*; the unflatten is
pure reshapes, free for XLA, and lets the rust coordinator manage exactly four
arrays per model (params, adam m, adam v, step). The sparse-KD loss calls the
L1 Pallas kernel (kernels/sparse_kld.py) so it lowers into the same HLO
module; `*_jnp` variants call the pure-jnp oracle for the L1-vs-L2 perf
ablation.

Architecture (paper Appendix F): RMSNorm, SwiGLU FFN, rotary embeddings,
grouped-query attention, untied output head, Adam(b1=0.9, b2=0.95) with
global-norm gradient clipping at 1.0.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ExportConfig, ModelDims
from .kernels import ref
from .kernels.sparse_kld import sparse_kld
from .kernels.sampler import sample_rs

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0
EPS = 1e-20


# --------------------------------------------------------------------------
# parameter layout
# --------------------------------------------------------------------------

def param_shapes(dims: ModelDims) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat layout."""
    d, v, ff, dh = dims.d_model, dims.vocab, dims.d_ff, dims.d_head
    shapes: List[Tuple[str, Tuple[int, ...]]] = [("tok_emb", (v, d))]
    for i in range(dims.n_layers):
        shapes += [
            (f"l{i}.attn_norm", (d,)),
            (f"l{i}.wq", (d, dims.n_heads * dh)),
            (f"l{i}.wk", (d, dims.n_kv_heads * dh)),
            (f"l{i}.wv", (d, dims.n_kv_heads * dh)),
            (f"l{i}.wo", (dims.n_heads * dh, d)),
            (f"l{i}.ffn_norm", (d,)),
            (f"l{i}.w1", (d, ff)),
            (f"l{i}.w3", (d, ff)),
            (f"l{i}.w2", (ff, d)),
        ]
    shapes += [("final_norm", (d,)), ("out_head", (d, v))]
    return shapes


def param_count(dims: ModelDims) -> int:
    total = 0
    for _, s in param_shapes(dims):
        n = 1
        for x in s:
            n *= x
        total += n
    return total


def unflatten(flat: jnp.ndarray, dims: ModelDims) -> Dict[str, jnp.ndarray]:
    params = {}
    off = 0
    for name, shape in param_shapes(dims):
        n = 1
        for x in shape:
            n *= x
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def init_flat(seed: jnp.ndarray, dims: ModelDims) -> jnp.ndarray:
    """Initial flat parameter vector from an int32 seed scalar (a graph!)."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    chunks = []
    for name, shape in param_shapes(dims):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            chunks.append(jnp.ones(shape, jnp.float32).reshape(-1))
        else:
            std = 0.02
            if name.endswith(("wo", "w2")):  # residual-branch scaling
                std = 0.02 / jnp.sqrt(2.0 * dims.n_layers)
            chunks.append((jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1))
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-5) * g


def _rope(x, theta: float):
    # x: [B, S, H, Dh] with Dh even
    b, s, h, dh = x.shape
    half = dh // 2
    freqs = jnp.power(theta, -jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward_logits(flat: jnp.ndarray, tokens: jnp.ndarray, dims: ModelDims,
                   rope_theta: float = 10000.0) -> jnp.ndarray:
    """[P], [B,S] int32 -> logits [B,S,V]."""
    p = unflatten(flat, dims)
    b, s = tokens.shape
    h, kv, dh = dims.n_heads, dims.n_kv_heads, dims.d_head
    x = p["tok_emb"][tokens]  # [B,S,D]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.finfo(jnp.float32).min
    for i in range(dims.n_layers):
        xa = _rmsnorm(x, p[f"l{i}.attn_norm"])
        q = (xa @ p[f"l{i}.wq"]).reshape(b, s, h, dh)
        k = (xa @ p[f"l{i}.wk"]).reshape(b, s, kv, dh)
        v = (xa @ p[f"l{i}.wv"]).reshape(b, s, kv, dh)
        q, k = _rope(q, rope_theta), _rope(k, rope_theta)
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, h * dh)
        x = x + o @ p[f"l{i}.wo"]
        xf = _rmsnorm(x, p[f"l{i}.ffn_norm"])
        x = x + (jax.nn.silu(xf @ p[f"l{i}.w1"]) * (xf @ p[f"l{i}.w3"])) @ p[f"l{i}.w2"]
    x = _rmsnorm(x, p["final_norm"])
    return x @ p["out_head"]


def forward_probs(flat, tokens, dims, rope_theta=10000.0):
    return jax.nn.softmax(forward_logits(flat, tokens, dims, rope_theta), axis=-1)


# --------------------------------------------------------------------------
# losses (per-token mean over B*S rows)
# --------------------------------------------------------------------------

def _ce_rows(logits2d, labels1d):
    logp = jax.nn.log_softmax(logits2d, axis=-1)
    return -jnp.take_along_axis(logp, labels1d[:, None], axis=-1)[:, 0]


def loss_ce(flat, tokens, labels, dims, cfg):
    logits = forward_logits(flat, tokens, dims, cfg.rope_theta)
    rows = _ce_rows(logits.reshape(-1, dims.vocab), labels.reshape(-1))
    return jnp.mean(rows)


def loss_dense(flat, tokens, labels, tprobs, alpha, dims, cfg, kind="kld"):
    logits = forward_logits(flat, tokens, dims, cfg.rope_theta).reshape(-1, dims.vocab)
    labels1 = labels.reshape(-1)
    kd = ref.dense_losses_ref(logits, tprobs.reshape(-1, dims.vocab), kind)
    ce = _ce_rows(logits, labels1)
    loss = alpha * jnp.mean(ce) + (1.0 - alpha) * jnp.mean(kd)
    return loss, jnp.mean(kd)


def loss_sparse(flat, tokens, labels, idx, val, alpha, smooth_c, ghost_on, lr_scale,
                dims, cfg, use_pallas=True):
    logits = forward_logits(flat, tokens, dims, cfg.rope_theta).reshape(-1, dims.vocab)
    r = logits.shape[0]
    labels1 = labels.reshape(-1)
    smooth = smooth_c.reshape(-1)  # per-row smoothing residual [B*S]
    ghost = jnp.broadcast_to(ghost_on, (r,))
    w = lr_scale.reshape(-1)
    fn = sparse_kld if use_pallas else ref.sparse_kld_ref
    kd = fn(logits, idx.reshape(r, -1), val.reshape(r, -1), smooth, ghost, w)
    ce = _ce_rows(logits, labels1) * w
    loss = alpha * jnp.mean(ce) + (1.0 - alpha) * jnp.mean(kd)
    return loss, jnp.mean(kd)


# --------------------------------------------------------------------------
# Adam-in-graph train step
# --------------------------------------------------------------------------

def adam_step(flat, m, v, step, lr, grads):
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    g = grads * scale
    step1 = step + 1
    m1 = ADAM_B1 * m + (1 - ADAM_B1) * g
    v1 = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    t = step1.astype(jnp.float32)
    mhat = m1 / (1 - jnp.power(ADAM_B1, t))
    vhat = v1 / (1 - jnp.power(ADAM_B2, t))
    flat1 = flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return flat1, m1, v1, step1


def make_graphs(cfg: ExportConfig, role: str, dims: ModelDims):
    """Returns {graph_name: (fn, arg_shape_dtype_structs)} for one model."""
    b, s, v, k, n = cfg.batch, cfg.seq, dims.vocab, cfg.k_slots, cfg.n_rounds
    pcount = param_count(dims)
    f32, i32 = jnp.float32, jnp.int32

    def sds(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    P = sds((pcount,))
    TOK = sds((b, s), i32)
    SCALAR = sds(())
    STEP = sds((), i32)
    TPROBS = sds((b, s, v))
    IDX = sds((b, s, k), i32)
    VAL = sds((b, s, k))
    LRS = sds((b, s))

    graphs = {}

    def init_fn(seed):
        return (init_flat(seed, dims),)

    graphs[f"init_{role}"] = (init_fn, [STEP])

    def fwd_fn(flat, tokens):
        return (forward_probs(flat, tokens, dims, cfg.rope_theta),)

    graphs[f"fwd_{role}"] = (fwd_fn, [P, TOK])

    def next_probs_fn(flat, tokens, pos):
        probs = forward_probs(flat, tokens, dims, cfg.rope_theta)
        return (jax.lax.dynamic_slice_in_dim(probs, pos, 1, axis=1)[:, 0, :],)

    graphs[f"next_probs_{role}"] = (next_probs_fn, [P, TOK, STEP])

    def train_ce_fn(flat, m, vv, step, lr, tokens, labels):
        loss, grads = jax.value_and_grad(loss_ce)(flat, tokens, labels, dims, cfg)
        flat1, m1, v1, step1 = adam_step(flat, m, vv, step, lr, grads)
        return flat1, m1, v1, step1, loss

    graphs[f"train_ce_{role}"] = (train_ce_fn, [P, P, P, STEP, SCALAR, TOK, TOK])

    def mk_train_dense(kind):
        def fn(flat, m, vv, step, lr, tokens, labels, tprobs, alpha):
            (loss, kd), grads = jax.value_and_grad(
                lambda f: loss_dense(f, tokens, labels, tprobs, alpha, dims, cfg, kind),
                has_aux=True,
            )(flat)
            flat1, m1, v1, step1 = adam_step(flat, m, vv, step, lr, grads)
            return flat1, m1, v1, step1, loss, kd

        return fn

    graphs[f"train_dense_{role}"] = (
        mk_train_dense("kld"), [P, P, P, STEP, SCALAR, TOK, TOK, TPROBS, SCALAR])
    if role == "student":
        for kind in ("rkl", "frkl", "mse", "l1"):
            graphs[f"train_dense_{kind}_{role}"] = (
                mk_train_dense(kind), [P, P, P, STEP, SCALAR, TOK, TOK, TPROBS, SCALAR])

    def mk_train_sparse(use_pallas):
        def fn(flat, m, vv, step, lr, tokens, labels, idx, val, alpha, smooth_c,
               ghost_on, lr_scale):
            (loss, kd), grads = jax.value_and_grad(
                lambda f: loss_sparse(f, tokens, labels, idx, val, alpha, smooth_c,
                                      ghost_on, lr_scale, dims, cfg, use_pallas),
                has_aux=True,
            )(flat)
            flat1, m1, v1, step1 = adam_step(flat, m, vv, step, lr, grads)
            return flat1, m1, v1, step1, loss, kd

        return fn

    sparse_args = [P, P, P, STEP, SCALAR, TOK, TOK, IDX, VAL, SCALAR, LRS, SCALAR, LRS]
    graphs[f"train_sparse_{role}"] = (mk_train_sparse(True), sparse_args)
    if role == "student":
        graphs[f"train_sparse_jnp_{role}"] = (mk_train_sparse(False), sparse_args)

    if role == "student":
        # gradient-only graphs for Table 3
        def grad_ce_fn(flat, tokens, labels):
            loss, grads = jax.value_and_grad(loss_ce)(flat, tokens, labels, dims, cfg)
            return grads, loss

        graphs[f"grad_ce_{role}"] = (grad_ce_fn, [P, TOK, TOK])

        def grad_dense_fn(flat, tokens, labels, tprobs, alpha):
            (loss, _), grads = jax.value_and_grad(
                lambda f: loss_dense(f, tokens, labels, tprobs, alpha, dims, cfg, "kld"),
                has_aux=True,
            )(flat)
            return grads, loss

        graphs[f"grad_dense_{role}"] = (grad_dense_fn, [P, TOK, TOK, TPROBS, SCALAR])

        def grad_sparse_fn(flat, tokens, labels, idx, val, alpha, smooth_c, ghost_on,
                           lr_scale):
            (loss, _), grads = jax.value_and_grad(
                lambda f: loss_sparse(f, tokens, labels, idx, val, alpha, smooth_c,
                                      ghost_on, lr_scale, dims, cfg, True),
                has_aux=True,
            )(flat)
            return grads, loss

        graphs[f"grad_sparse_{role}"] = (
            grad_sparse_fn, [P, TOK, TOK, IDX, VAL, SCALAR, LRS, SCALAR, LRS])

    def eval_fn(flat, tokens, labels):
        logits = forward_logits(flat, tokens, dims, cfg.rope_theta)
        logits2 = logits.reshape(-1, v)
        labels1 = labels.reshape(-1)
        logp = jax.nn.log_softmax(logits2, axis=-1)
        probs = jnp.exp(logp)
        loss_sum = -jnp.sum(jnp.take_along_axis(logp, labels1[:, None], axis=-1))
        conf = jnp.max(probs, axis=-1).reshape(b, s)
        correct = (jnp.argmax(probs, axis=-1) == labels1).astype(f32).reshape(b, s)
        label_prob = jnp.take_along_axis(probs, labels1[:, None], axis=-1)[:, 0].reshape(b, s)
        return loss_sum, conf, correct, label_prob

    graphs[f"eval_{role}"] = (eval_fn, [P, TOK, TOK])

    def agree_fn(flat, tokens, tprobs):
        sp_ = forward_probs(flat, tokens, dims, cfg.rope_theta)
        accept = jnp.sum(jnp.minimum(sp_, tprobs), axis=-1)  # spec-decode accept prob
        agree = (jnp.argmax(sp_, axis=-1) == jnp.argmax(tprobs, axis=-1)).astype(f32)
        return accept, agree

    graphs[f"agree_{role}"] = (agree_fn, [P, TOK, TPROBS])

    return graphs


def make_sampler_graphs(cfg: ExportConfig):
    b, s, v, k, n = cfg.batch, cfg.seq, cfg.vocab, cfg.k_slots, cfg.n_rounds
    f32, i32 = jnp.float32, jnp.int32

    def sds(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    graphs = {}

    def sample_rs_fn(probs, unif, temp):
        r = b * s
        ids, w = sample_rs(probs.reshape(r, v), unif.reshape(r, n),
                           jnp.broadcast_to(temp, (r,)))
        return ids.reshape(b, s, n), w.reshape(b, s, n)

    graphs["sample_rs"] = (sample_rs_fn, [sds((b, s, v)), sds((b, s, n)), sds(())])

    def sample_topk_fn(probs):
        # NOTE: jax.lax.top_k lowers to the `topk(..., largest=true)` HLO op,
        # which xla_extension 0.5.1's text parser rejects; a full sort lowers
        # to the classic variadic `sort` op and round-trips cleanly.
        p2 = probs.reshape(-1, v)
        order = jnp.argsort(-p2, axis=-1)[:, :k]
        vals = jnp.take_along_axis(p2, order, axis=-1)
        return order.astype(i32).reshape(b, s, k), vals.reshape(b, s, k)

    graphs["sample_topk"] = (sample_topk_fn, [sds((b, s, v))])

    return graphs
