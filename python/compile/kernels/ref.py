"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest + hypothesis assert the
interpret-mode Pallas kernels match these (allclose), and the L2 `*_jnp`
graph variants (used for the L1-vs-L2 perf ablation) call these directly.

Shapes use R = number of rows (= batch * seq after flattening), V = vocab,
K = sparse slots, N = sampling rounds.
"""

import jax
import jax.numpy as jnp

EPS = 1e-20


def scatter_targets(idx: jnp.ndarray, val: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Dense targets [R, V] from sparse (idx, val) [R, K]; duplicate ids add."""
    r, _k = idx.shape
    out = jnp.zeros((r, vocab), dtype=val.dtype)
    return out.at[jnp.arange(r)[:, None], idx].add(val)


def sparse_kld_ref(
    logits: jnp.ndarray,  # [R, V] student logits
    idx: jnp.ndarray,  # [R, K] int32 target token ids
    val: jnp.ndarray,  # [R, K] target probabilities (slots with val=0 are padding)
    smooth_c: jnp.ndarray,  # [R] uniform-smoothing constant added to every class
    ghost_on: jnp.ndarray,  # [R] 0/1: add the ghost-token residual term (Appendix A.5)
    weight: jnp.ndarray,  # [R] per-token loss scale (Table 9 adaptive LR)
) -> jnp.ndarray:
    """Generalized sparse softmax-KLD loss per row (paper Eq. 3 restricted to
    the sparse support, Appendix A.4/A.5). Returns [R] losses."""
    vocab = logits.shape[-1]
    t = scatter_targets(idx, val, vocab) + smooth_c[:, None]
    logp = jax.nn.log_softmax(logits, axis=-1)
    kld = jnp.sum(jnp.where(t > 0, t * (jnp.log(jnp.maximum(t, EPS)) - logp), 0.0), axis=-1)

    # ghost token: one pseudo-class holding the residual mass for both sides
    p = jax.nn.softmax(logits, axis=-1)
    support = scatter_targets(idx, (val > 0).astype(val.dtype), vocab) > 0
    s_t = jnp.sum(jnp.where(support, t, 0.0), axis=-1)
    # residual student mass summed directly over non-support tokens: stable
    # even when the support covers nearly all of the vocabulary
    rt = jnp.maximum(1.0 - s_t, EPS)
    rp = jnp.maximum(jnp.sum(jnp.where(support, 0.0, p), axis=-1), EPS)
    ghost = rt * (jnp.log(rt) - jnp.log(rp))
    return weight * (kld + ghost_on * ghost)


def sparse_kld_grad_ref(logits, idx, val, smooth_c, ghost_on, weight, cotangent):
    """Hand-derived gradient wrt logits (paper Appendix A.4 + A.5):
        base:   (sum_t) * p_j - t_j
        ghost:  + (1 - s_t)/(1 - s_p) * (p_j * 1{j in K} - s_p * p_j)
    Returns [R, V]."""
    vocab = logits.shape[-1]
    t = scatter_targets(idx, val, vocab) + smooth_c[:, None]
    p = jax.nn.softmax(logits, axis=-1)
    sum_t = jnp.sum(t, axis=-1, keepdims=True)
    g = sum_t * p - t

    support = scatter_targets(idx, (val > 0).astype(val.dtype), vocab) > 0
    s_t = jnp.sum(jnp.where(support, t, 0.0), axis=-1, keepdims=True)
    s_p = jnp.sum(jnp.where(support, p, 0.0), axis=-1, keepdims=True)
    rp = jnp.maximum(jnp.sum(jnp.where(support, 0.0, p), axis=-1, keepdims=True), EPS)
    ratio = jnp.maximum(1.0 - s_t, EPS) / rp
    g_ghost = ratio * (p * support.astype(p.dtype) - s_p * p)
    g = g + ghost_on[:, None] * g_ghost
    return g * (weight * cotangent)[:, None]


def sample_rs_ref(probs: jnp.ndarray, unif: jnp.ndarray, temp: jnp.ndarray):
    """Importance sampling from proposal q ∝ p^temp via inverse-transform
    sampling (paper §3.4 + Appendix K). Returns (ids [R,N] int32,
    weights [R,N] f32) with per-row weights summing to 1; duplicate draws keep
    separate slots and merge when scattered."""
    vocab = probs.shape[-1]
    q = jnp.power(jnp.maximum(probs, EPS), temp[:, None])
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    cq = jnp.cumsum(q, axis=-1)
    # searchsorted-right, branch-free: id = #{v : u > cq_v}
    ids = jnp.sum((unif[:, :, None] > cq[:, None, :]).astype(jnp.int32), axis=-1)
    ids = jnp.clip(ids, 0, vocab - 1).astype(jnp.int32)
    p_at = jnp.take_along_axis(probs, ids, axis=-1)
    q_at = jnp.take_along_axis(q, ids, axis=-1)
    ratio = p_at / jnp.maximum(q_at, EPS)
    weights = ratio / jnp.maximum(jnp.sum(ratio, axis=-1, keepdims=True), EPS)
    return ids, weights.astype(probs.dtype)


def dense_losses_ref(logits, tprobs, kind: str):
    """Dense-target losses for the Table 12 ablation. Returns [R]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    t = tprobs
    if kind == "kld":  # forward KLD
        return jnp.sum(jnp.where(t > 0, t * (jnp.log(jnp.maximum(t, EPS)) - logp), 0.0), axis=-1)
    if kind == "rkl":  # reverse KLD
        return jnp.sum(p * (logp - jnp.log(jnp.maximum(t, EPS))), axis=-1)
    if kind == "frkl":
        return 0.5 * dense_losses_ref(logits, tprobs, "kld") + 0.5 * dense_losses_ref(
            logits, tprobs, "rkl"
        )
    if kind == "mse":
        return jnp.sum((p - t) ** 2, axis=-1) * t.shape[-1]
    if kind == "l1":
        return jnp.sum(jnp.abs(p - t), axis=-1) * t.shape[-1]
    raise ValueError(kind)
