"""L1 Pallas kernel: fused sparse softmax-KLD loss with hand-derived backward.

This is the paper's compute hot-spot (Appendix D.2: "Manual backward and
forward for the softmax KLD needed to be implemented"). The kernel fuses:

    scatter(idx, val) -> dense target  +  log-softmax  +  generalized KLD
    (+ optional uniform smoothing constant, + optional ghost-token residual)

into a single pass over the vocabulary axis, never materializing the dense
[R, V] target in HBM. The backward kernel emits the paper's closed-form
gradient (Appendix A.4/A.5):

    base:   g_j = (sum_i t_i) * p_j - t_j
    ghost: +      (1 - s_t)/(1 - s_p) * (p_j * 1{j in support} - s_p * p_j)

TPU mapping (DESIGN.md §6): grid over row-tiles; each grid step holds one
row-block of logits plus the K-slot sparse target in VMEM. On CPU we must run
interpret=True (real lowering emits a Mosaic custom-call the CPU PJRT plugin
cannot execute); numerics are identical and validated against ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-20


def _dense_from_sparse(idx, val, vocab):
    """In-VMEM scatter: one-hot contraction over the K slot axis.

    [RB, K] x [RB, K, V] -> [RB, V]. On TPU this is a K-step VPU loop over
    lane tiles; under interpret it is a plain einsum. Duplicate ids add."""
    onehot = (idx[:, :, None] == jax.lax.iota(jnp.int32, vocab)[None, None, :]).astype(val.dtype)
    dense = jnp.einsum("rk,rkv->rv", val, onehot)
    support = jnp.einsum("rk,rkv->rv", (val > 0).astype(val.dtype), onehot) > 0
    return dense, support


def _fwd_kernel(logits_ref, idx_ref, val_ref, smooth_ref, ghost_ref, w_ref, loss_ref):
    x = logits_ref[...]
    vocab = x.shape[-1]
    t, support = _dense_from_sparse(idx_ref[...], val_ref[...], vocab)
    t = t + smooth_ref[...][:, None]

    m = jnp.max(x, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True)) + m
    logp = x - lse
    kld = jnp.sum(jnp.where(t > 0, t * (jnp.log(jnp.maximum(t, EPS)) - logp), 0.0), axis=-1)

    p = jnp.exp(logp)
    s_t = jnp.sum(jnp.where(support, t, 0.0), axis=-1)
    # residual student mass summed directly over non-support tokens (stable
    # when the support covers nearly the whole vocab row)
    rt = jnp.maximum(1.0 - s_t, EPS)
    rp = jnp.maximum(jnp.sum(jnp.where(support, 0.0, p), axis=-1), EPS)
    ghost = rt * (jnp.log(rt) - jnp.log(rp))

    loss_ref[...] = w_ref[...] * (kld + ghost_ref[...] * ghost)


def _bwd_kernel(logits_ref, idx_ref, val_ref, smooth_ref, ghost_ref, w_ref, ct_ref, gx_ref):
    x = logits_ref[...]
    vocab = x.shape[-1]
    t, support = _dense_from_sparse(idx_ref[...], val_ref[...], vocab)
    t = t + smooth_ref[...][:, None]

    # shared recomputation with fwd: row max + logsumexp
    m = jnp.max(x, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True)) + m
    p = jnp.exp(x - lse)

    sum_t = jnp.sum(t, axis=-1, keepdims=True)
    g = sum_t * p - t

    s_t = jnp.sum(jnp.where(support, t, 0.0), axis=-1, keepdims=True)
    s_p = jnp.sum(jnp.where(support, p, 0.0), axis=-1, keepdims=True)
    rp = jnp.maximum(jnp.sum(jnp.where(support, 0.0, p), axis=-1, keepdims=True), EPS)
    ratio = jnp.maximum(1.0 - s_t, EPS) / rp
    g_ghost = ratio * (p * support.astype(p.dtype) - s_p * p)

    g = g + ghost_ref[...][:, None] * g_ghost
    gx_ref[...] = g * (w_ref[...] * ct_ref[...])[:, None]


def _block_rows(r: int) -> int:
    for rb in (64, 32, 16, 8, 4, 2, 1):
        if r % rb == 0:
            return rb
    return 1


def _row_specs(rb, v, k):
    return [
        pl.BlockSpec((rb, v), lambda i: (i, 0)),  # logits
        pl.BlockSpec((rb, k), lambda i: (i, 0)),  # idx
        pl.BlockSpec((rb, k), lambda i: (i, 0)),  # val
        pl.BlockSpec((rb,), lambda i: (i,)),  # smooth
        pl.BlockSpec((rb,), lambda i: (i,)),  # ghost
        pl.BlockSpec((rb,), lambda i: (i,)),  # weight
    ]


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def sparse_kld(logits, idx, val, smooth_c, ghost_on, weight):
    """Fused sparse softmax-KLD loss. [R,V],[R,K],[R,K],[R],[R],[R] -> [R]."""
    return _sparse_kld_fwd(logits, idx, val, smooth_c, ghost_on, weight)[0]


def _sparse_kld_fwd(logits, idx, val, smooth_c, ghost_on, weight):
    r, v = logits.shape
    k = idx.shape[-1]
    rb = _block_rows(r)
    loss = pl.pallas_call(
        _fwd_kernel,
        grid=(r // rb,),
        in_specs=_row_specs(rb, v, k),
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), logits.dtype),
        interpret=True,
    )(logits, idx, val, smooth_c, ghost_on, weight)
    return loss, (logits, idx, val, smooth_c, ghost_on, weight)


def _sparse_kld_bwd(res, ct):
    logits, idx, val, smooth_c, ghost_on, weight = res
    r, v = logits.shape
    k = idx.shape[-1]
    rb = _block_rows(r)
    specs = _row_specs(rb, v, k)
    specs.append(pl.BlockSpec((rb,), lambda i: (i,)))  # cotangent
    gx = pl.pallas_call(
        _bwd_kernel,
        grid=(r // rb,),
        in_specs=specs,
        out_specs=pl.BlockSpec((rb, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, v), logits.dtype),
        interpret=True,
    )(logits, idx, val, smooth_c, ghost_on, weight, ct)
    # only the logits receive a gradient; sparse targets and knobs are data
    return gx, None, None, None, None, None


sparse_kld.defvjp(_sparse_kld_fwd, _sparse_kld_bwd)
