"""L1 Pallas kernel: inverse-transform importance sampler over the vocab axis.

Implements the paper's Random Sampling KD draw (§3.4 + Appendix K): sample N
tokens per row from the proposal q ∝ p^temp, weight each draw by the
likelihood ratio p/q, normalize. For temp=1 this degenerates to counts/N
exactly (ratio = 1), matching the paper's pseudocode.

Formulated branch-free for the VPU: cumsum over the vocab row, then
searchsorted of the N uniforms as a compare-and-sum over lane tiles rather
than a serial binary search. interpret=True on CPU (see sparse_kld.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-20


def _sampler_kernel(probs_ref, unif_ref, temp_ref, ids_ref, w_ref):
    p = probs_ref[...]  # [RB, V]
    u = unif_ref[...]  # [RB, N]
    t = temp_ref[...]  # [RB]
    vocab = p.shape[-1]

    q = jnp.power(jnp.maximum(p, EPS), t[:, None])
    q = q / jnp.sum(q, axis=-1, keepdims=True)
    cq = jnp.cumsum(q, axis=-1)

    # searchsorted-right: id = #{v : u > cq_v}; branch-free compare-and-sum
    ids = jnp.sum((u[:, :, None] > cq[:, None, :]).astype(jnp.int32), axis=-1)
    ids = jnp.clip(ids, 0, vocab - 1).astype(jnp.int32)

    p_at = jnp.take_along_axis(p, ids, axis=-1)
    q_at = jnp.take_along_axis(q, ids, axis=-1)
    ratio = p_at / jnp.maximum(q_at, EPS)
    w = ratio / jnp.maximum(jnp.sum(ratio, axis=-1, keepdims=True), EPS)

    ids_ref[...] = ids
    w_ref[...] = w.astype(p.dtype)


def _block_rows(r: int) -> int:
    for rb in (64, 32, 16, 8, 4, 2, 1):
        if r % rb == 0:
            return rb
    return 1


def sample_rs(probs, unif, temp):
    """[R,V] probs, [R,N] uniforms, [R] temperature -> (ids [R,N] i32, w [R,N])."""
    r, v = probs.shape
    n = unif.shape[-1]
    rb = _block_rows(r)
    ids, w = pl.pallas_call(
        _sampler_kernel,
        grid=(r // rb,),
        in_specs=[
            pl.BlockSpec((rb, v), lambda i: (i, 0)),
            pl.BlockSpec((rb, n), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((rb, n), lambda i: (i, 0)),
            pl.BlockSpec((rb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.int32),
            jax.ShapeDtypeStruct((r, n), probs.dtype),
        ],
        interpret=True,
    )(probs, unif, temp)
    return ids, w
