"""Model / export configurations for the RS-KD reproduction.

Dims are scaled to CPU-PJRT (see DESIGN.md §4): every claim under test is
distribution-level, so we keep the LLaMA-style architecture (RMSNorm, SwiGLU,
RoPE, GQA) but shrink widths. A config names a *teacher→student pair* plus the
batch geometry shared by every exported graph.
"""

from dataclasses import dataclass, field
from typing import Dict


@dataclass(frozen=True)
class ModelDims:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, v, ff = self.d_model, self.vocab, self.d_ff
        dh = self.d_head
        per_layer = (
            d  # attn norm
            + d * self.n_heads * dh  # wq
            + 2 * d * self.n_kv_heads * dh  # wk, wv
            + self.n_heads * dh * d  # wo
            + d  # ffn norm
            + 3 * d * ff  # w1, w3, w2
        )
        return v * d + self.n_layers * per_layer + d + d * v  # emb + layers + final norm + head


@dataclass(frozen=True)
class ExportConfig:
    name: str
    teacher: ModelDims
    students: Dict[str, ModelDims]  # role name -> dims ("student" is the main one)
    batch: int = 8
    seq: int = 64
    k_slots: int = 64  # static sparse-target slot count (covers Top-K<=64 and N<=64 RS rounds)
    n_rounds: int = 50  # RS sampling slots in the sampler graph
    rope_theta: float = 10000.0

    @property
    def vocab(self) -> int:
        return self.teacher.vocab


def _dims(vocab, d, layers, heads, kv, ff) -> ModelDims:
    return ModelDims(vocab=vocab, d_model=d, n_layers=layers, n_heads=heads,
                     n_kv_heads=kv, d_ff=ff)


V = 512

CONFIGS: Dict[str, ExportConfig] = {
    # main working config: "3B teacher -> 300M student" analogue
    "small": ExportConfig(
        name="small",
        teacher=_dims(V, 128, 4, 4, 2, 256),
        students={"student": _dims(V, 64, 2, 4, 2, 128)},
    ),
    # "8B teacher -> 3B student" analogue (Tables 7, 8)
    "large": ExportConfig(
        name="large",
        teacher=_dims(V, 256, 4, 8, 4, 512),
        students={"student": _dims(V, 128, 4, 4, 2, 256)},
    ),
    # Figure 4 student-size sweep (shared teacher = small's teacher)
    "sizes": ExportConfig(
        name="sizes",
        teacher=_dims(V, 128, 4, 4, 2, 256),
        students={
            "s0": _dims(V, 32, 2, 2, 1, 64),
            "s1": _dims(V, 48, 2, 2, 1, 96),
            "s2": _dims(V, 64, 2, 4, 2, 128),
            "s3": _dims(V, 96, 3, 4, 2, 192),
        },
    ),
}
